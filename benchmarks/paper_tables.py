"""One benchmark per Galaxy paper table/figure, driven by the calibrated
simulator (cost model validated against Table I) + the faithful planner.

Each function yields (name, us_per_call, derived) rows.
"""
from __future__ import annotations

from typing import Iterator, Tuple

from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core import simulator as sim

Row = Tuple[str, float, str]
SEQ = 284  # paper: QNLI subset, average sequence length 284


def _fmt(v) -> str:
    return v if isinstance(v, str) else f"{v:.2f}x"


def table1_ondevice() -> Iterator[Row]:
    """Table I: on-device latency + memory footprint (Nano-M, seq 30)."""
    dev = [cm.jetson_nano("nano-m", 1.5)]
    for name in ("distilbert", "bert-l", "gpt2-l", "opt-l", "opt-xl"):
        cfg = get_config(name)
        r = sim.simulate(cfg, dev, cm.mbps(125), 30, "local")
        mem_mb = cm.model_memory_bytes(cfg) / 1e6
        lat = r.latency * 1e6 if not r.oom else float("nan")
        yield (f"table1/{name}", lat, f"mem={mem_mb:.0f}MB" + (",OOM" if r.oom else ""))


def table4_general() -> Iterator[Row]:
    """Table IV: Galaxy vs M-LM / SP on homogeneous envs A/B/C @125Mbps."""
    cases = [
        ("distilbert", "A"), ("bert-l", "A"), ("bert-l", "B"),
        ("gpt2-l", "A"), ("gpt2-l", "B"),
        ("opt-l", "A"), ("opt-l", "B"), ("opt-l", "C"),
        ("opt-xl", "A"), ("opt-xl", "B"), ("opt-xl", "C"),
    ]
    for model, env in cases:
        t = sim.speedup_table(get_config(model), cm.edge_env(env), cm.mbps(125), SEQ)
        lat = t["galaxy_s"] * 1e6 if isinstance(t["galaxy_s"], float) else float("nan")
        yield (
            f"table4/{model}/env{env}", lat,
            f"vsM-LM={_fmt(t['megatron'])},vsSP={_fmt(t['sp'])}",
        )


def table5_gpu() -> Iterator[Row]:
    """Table V: mobile-GPU env (2x Nano GPU @460MHz, 500Mbps)."""
    devs = [cm.jetson_nano_gpu(6.0)] * 2
    for model in ("distilbert", "bert-l", "gpt2-l", "opt-l", "opt-xl"):
        t = sim.speedup_table(get_config(model), devs, cm.mbps(500), SEQ)
        lat = t["galaxy_s"] * 1e6
        yield (
            f"table5/{model}/gpu", lat,
            f"vsM-LM={_fmt(t['megatron'])},vsSP={_fmt(t['sp'])}",
        )


def fig8_bandwidth() -> Iterator[Row]:
    """Fig. 8: speedup across D2D bandwidths (bert-l + opt-l, env B)."""
    for model in ("bert-l", "opt-l"):
        for mb in (62.5, 125, 250, 500, 1000):
            t = sim.speedup_table(get_config(model), cm.edge_env("B"), cm.mbps(mb), SEQ)
            lat = t["galaxy_s"] * 1e6
            yield (f"fig8/{model}/{mb:g}Mbps", lat, f"vsM-LM={_fmt(t['megatron'])}")


def fig9_heterogeneous() -> Iterator[Row]:
    """Fig. 9: heterogeneous envs D/E/F (capacity+memory-aware planning)."""
    for model in ("bert-l", "gpt2-l"):
        for env in ("D", "E", "F"):
            t = sim.speedup_table(get_config(model), cm.edge_env(env), cm.mbps(125), SEQ)
            lat = t["galaxy_s"] * 1e6 if isinstance(t["galaxy_s"], float) else float("nan")
            yield (
                f"fig9/{model}/env{env}", lat,
                f"vsM-LM={_fmt(t['megatron'])},vsSP={_fmt(t['sp'])}",
            )


def fig10_weak_scaling() -> Iterator[Row]:
    for model, paper in (("gpt2-l", 0.81), ("opt-xl", 0.86)):
        effs = sim.weak_scaling(get_config(model), cm.jetson_nano("nano-m", 1.5),
                                cm.mbps(1000), 96)
        for d, e in enumerate(effs, start=1):
            yield (f"fig10/{model}/{d}dev", float("nan"),
                   f"eff={e*100:.0f}%" + (f",paper@4={paper*100:.0f}%" if d == 4 else ""))


def fig11_strong_scaling() -> Iterator[Row]:
    for model, paper in (("gpt2-l", 3.05), ("opt-xl", 3.24)):
        sps = sim.strong_scaling(get_config(model), cm.jetson_nano("nano-m", 1.5),
                                 cm.mbps(1000), 384)
        for d, s in enumerate(sps, start=1):
            yield (f"fig11/{model}/{d}dev", float("nan"),
                   f"speedup={s:.2f}x" + (f",paper@4={paper:.2f}x" if d == 4 else ""))


ALL = [
    table1_ondevice, table4_general, table5_gpu,
    fig8_bandwidth, fig9_heterogeneous, fig10_weak_scaling, fig11_strong_scaling,
]
