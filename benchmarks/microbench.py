"""Real timed microbenchmarks on this host (CPU): HMP schedules vs
baselines on a multi-device subprocess, kernel fusion wins, and the
Galaxy profiler's measured block latencies.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]


def _time(fn, *args, iters=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def kernel_fusion() -> Iterator[Row]:
    """fused_connective (1 HBM pass) vs unfused dropout+residual+LN."""
    from repro.kernels.ops import fused_connective
    from repro.kernels.ref import fused_connective_ref

    s, d = 2048, 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (s, d))
    res = jax.random.normal(jax.random.PRNGKey(1), (s, d))
    mask = jnp.ones((s, d))
    scale, bias = jnp.ones((d,)), jnp.zeros((d,))
    unfused = jax.jit(lambda *a: fused_connective_ref(*a, rate=0.0))
    t_ref = _time(unfused, x, res, mask, scale, bias)
    t_fused = _time(lambda *a: fused_connective(*a, rate=0.0), x, res, mask, scale, bias)
    yield ("micro/connective_unfused", t_ref, "jnp 3-pass")
    yield ("micro/connective_fused", t_fused, f"pallas 1-pass,{t_ref/t_fused:.2f}x")


def flash_vs_naive() -> Iterator[Row]:
    from repro.kernels.ops import flash_attention
    from repro.kernels.ref import flash_attention_ref

    b, h, s, hd = 1, 8, 1024, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, hd))
    t_naive = _time(jax.jit(lambda q, k, v: flash_attention_ref(q, k, v)), q, k, v, iters=3)
    t_flash = _time(lambda q, k, v: flash_attention(q, k, v), q, k, v, iters=3)
    yield ("micro/attention_naive", t_naive, "materialized scores")
    yield ("micro/attention_flash", t_flash,
           "pallas blocked (interpret on CPU; wins are on-TPU)")


def profiler_blocks() -> Iterator[Row]:
    """Galaxy Profiler measuring real block latencies (paper step 1)."""
    from repro.configs import get_config
    from repro.core.profiler import HostProfiler

    prof = HostProfiler(get_config("distilbert"), seq=128, iters=3)
    t = prof.measure_blocks(heads=12, columns=3072)
    yield ("micro/profiler_mha_full", t["mha"] * 1e6, "L(MHA,full,host)")
    yield ("micro/profiler_mlp_full", t["mlp"] * 1e6, "L(MLP,full,host)")
    yield ("micro/profiler_con_full", t["con"] * 1e6, "L(CON,full,host)")
    half = prof.measure_blocks(heads=6, columns=1536)
    yield ("micro/profiler_mha_half", half["mha"] * 1e6,
           f"half-partition,{t['mha']/half['mha']:.2f}x")


def hmp_schedules_multidevice() -> Iterator[Row]:
    """Per-layer wall time of hmp / hmp_ring / megatron / sp on 4 CPU
    devices (subprocess) — the real executable of the paper's comparison.
    CPU ppermute/collectives are emulation-grade; relative numbers only."""
    code = r"""
import jax, jax.numpy as jnp, time
from repro.core import hmp
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4,), ('model',))
p = hmp.init_layer_params(jax.random.PRNGKey(0), 256, 8, 1024)
x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 256))
for name, fn in hmp.SCHEDULES.items():
    f = jax.jit(lambda p, x, fn=fn: fn(p, x, mesh))
    out = f(p, x); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(10):
        out = f(p, x)
    jax.block_until_ready(out)
    print(f"{name},{(time.perf_counter()-t0)/10*1e6:.1f}")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        yield ("micro/hmp_schedules", float("nan"), "subprocess failed")
        return
    rows = dict(line.split(",") for line in proc.stdout.strip().splitlines())
    base = float(rows.get("megatron", "nan"))
    for name, us in rows.items():
        yield (f"micro/layer_{name}", float(us),
               f"vs megatron={base/float(us):.2f}x" if base == base else "")


def execplan_uneven() -> Iterator[Row]:
    """Measured vs simulated latency of the *same* uneven ExecPlan.

    The planner partitions a DistilBert layer over a 3:2:2:1 heterogeneous
    cluster; the resulting ExecPlan is (a) scored by the simulator (assigned
    workload and padded SPMD workload) and (b) executed for real through
    hmp / hmp_ring on 4 forced CPU devices.  Absolute scales differ (host
    CPU vs simulated Jetsons) — the point is one plan flowing through both.
    """
    import dataclasses

    from repro.configs import get_config
    from repro.core import costmodel, planner
    from repro.core.execplan import ExecPlan
    from repro.core.profiler import AnalyticProfiler
    from repro.core.simulator import simulate_execplan

    seq = 128
    cfg = dataclasses.replace(get_config("distilbert"), num_layers=1)
    caps = [3.0, 2.0, 2.0, 1.0]
    devices = [
        costmodel.DeviceSpec(f"edge{i}", flops=c * 7.1e9, mem_bw=4.0e9,
                             memory_budget=1.5e9)
        for i, c in enumerate(caps)
    ]
    link = costmodel.mbps(1000)
    prof = AnalyticProfiler(cfg, seq)
    pl = planner.plan(prof.model_profile(), prof.device_profiles(devices))
    if not pl.feasible:
        yield ("micro/execplan", float("nan"), f"plan infeasible:{pl.reason}")
        return
    eplan = ExecPlan.from_plan(pl, head_dim=cfg.head_dim, d_model=cfg.d_model)

    for name, padded, overlap in [
        ("sim/execplan_galaxy", False, False),
        ("sim/execplan_galaxy_overlap", False, True),
        ("sim/execplan_galaxy_overlap_padded", True, True),
    ]:
        r = simulate_execplan(eplan, cfg, devices, link, seq,
                              overlap=overlap, padded=padded)
        yield (name, r.latency * 1e6,
               f"simulated,{eplan.describe()}" if not padded else
               "simulated,every device runs max(units)")

    code = rf"""
import jax, jax.numpy as jnp, time
from repro.core import hmp
from repro.core.execplan import ExecPlan
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4,), ('model',))
eplan = ExecPlan(heads={tuple(eplan.heads)}, columns={tuple(eplan.columns)},
                 head_dim={eplan.head_dim}, d_model={eplan.d_model})
p = hmp.init_layer_params(jax.random.PRNGKey(0), eplan.d_model,
                          eplan.num_heads, eplan.d_ff)
pp = eplan.pad_layer_params(p)
x = jax.random.normal(jax.random.PRNGKey(1), (1, {seq}, eplan.d_model))
for name, overlap in [('hmp', False), ('hmp_ring', True)]:
    f = jax.jit(lambda p, x, o=overlap: hmp.hmp_layer(p, x, mesh, overlap=o,
                                                      plan=eplan))
    out = f(pp, x); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(10):
        out = f(pp, x)
    jax.block_until_ready(out)
    print(f"{{name}},{{(time.perf_counter()-t0)/10*1e6:.1f}}")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        yield ("micro/execplan", float("nan"), "subprocess failed")
        return
    for line in proc.stdout.strip().splitlines():
        name, us = line.split(",")
        yield (f"micro/execplan_{name}", float(us),
               f"measured,heads={list(eplan.heads)},cols={list(eplan.columns)}")


ALL = [kernel_fusion, flash_vs_naive, profiler_blocks,
       hmp_schedules_multidevice, execplan_uneven]
