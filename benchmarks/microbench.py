"""Real timed microbenchmarks on this host (CPU): HMP schedules vs
baselines on a multi-device subprocess, kernel fusion wins, and the
Galaxy profiler's measured block latencies.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]


def _time(fn, *args, iters=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def kernel_fusion() -> Iterator[Row]:
    """fused_connective (1 HBM pass) vs unfused dropout+residual+LN."""
    from repro.kernels.ops import fused_connective
    from repro.kernels.ref import fused_connective_ref

    s, d = 2048, 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (s, d))
    res = jax.random.normal(jax.random.PRNGKey(1), (s, d))
    mask = jnp.ones((s, d))
    scale, bias = jnp.ones((d,)), jnp.zeros((d,))
    unfused = jax.jit(lambda *a: fused_connective_ref(*a, rate=0.0))
    t_ref = _time(unfused, x, res, mask, scale, bias)
    t_fused = _time(lambda *a: fused_connective(*a, rate=0.0), x, res, mask, scale, bias)
    yield ("micro/connective_unfused", t_ref, "jnp 3-pass")
    yield ("micro/connective_fused", t_fused, f"pallas 1-pass,{t_ref/t_fused:.2f}x")


def flash_vs_naive() -> Iterator[Row]:
    from repro.kernels.ops import flash_attention
    from repro.kernels.ref import flash_attention_ref

    b, h, s, hd = 1, 8, 1024, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, hd))
    t_naive = _time(jax.jit(lambda q, k, v: flash_attention_ref(q, k, v)), q, k, v, iters=3)
    t_flash = _time(lambda q, k, v: flash_attention(q, k, v), q, k, v, iters=3)
    yield ("micro/attention_naive", t_naive, "materialized scores")
    yield ("micro/attention_flash", t_flash,
           "pallas blocked (interpret on CPU; wins are on-TPU)")


def profiler_blocks() -> Iterator[Row]:
    """Galaxy Profiler measuring real block latencies (paper step 1)."""
    from repro.configs import get_config
    from repro.core.profiler import HostProfiler

    prof = HostProfiler(get_config("distilbert"), seq=128, iters=3)
    t = prof.measure_blocks(heads=12, columns=3072)
    yield ("micro/profiler_mha_full", t["mha"] * 1e6, "L(MHA,full,host)")
    yield ("micro/profiler_mlp_full", t["mlp"] * 1e6, "L(MLP,full,host)")
    yield ("micro/profiler_con_full", t["con"] * 1e6, "L(CON,full,host)")
    half = prof.measure_blocks(heads=6, columns=1536)
    yield ("micro/profiler_mha_half", half["mha"] * 1e6,
           f"half-partition,{t['mha']/half['mha']:.2f}x")


def hmp_schedules_multidevice() -> Iterator[Row]:
    """Per-layer wall time of hmp / hmp_ring / megatron / sp on 4 CPU
    devices (subprocess) — the real executable of the paper's comparison.
    CPU ppermute/collectives are emulation-grade; relative numbers only."""
    code = r"""
import jax, jax.numpy as jnp, time
from jax.sharding import AxisType
from repro.core import hmp
mesh = jax.make_mesh((4,), ('model',), axis_types=(AxisType.Auto,))
p = hmp.init_layer_params(jax.random.PRNGKey(0), 256, 8, 1024)
x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 256))
for name, fn in hmp.SCHEDULES.items():
    f = jax.jit(lambda p, x, fn=fn: fn(p, x, mesh))
    out = f(p, x); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(10):
        out = f(p, x)
    jax.block_until_ready(out)
    print(f"{name},{(time.perf_counter()-t0)/10*1e6:.1f}")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        yield ("micro/hmp_schedules", float("nan"), "subprocess failed")
        return
    rows = dict(line.split(",") for line in proc.stdout.strip().splitlines())
    base = float(rows.get("megatron", "nan"))
    for name, us in rows.items():
        yield (f"micro/layer_{name}", float(us),
               f"vs megatron={base/float(us):.2f}x" if base == base else "")


ALL = [kernel_fusion, flash_vs_naive, profiler_blocks, hmp_schedules_multidevice]
