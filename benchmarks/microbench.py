"""Real timed microbenchmarks on this host (CPU): HMP schedules vs
baselines on a multi-device subprocess, kernel fusion wins, and the
Galaxy profiler's measured block latencies.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _time(fn, *args, iters=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def measure_execplan_layers(eplan, seq: int, *, devices: int = 4,
                            iters: int = 10) -> dict:
    """Measured per-layer wall time (seconds) of hmp / hmp_ring executing an
    ExecPlan on forced CPU devices.

    The one measurement harness shared by the execplan benches below and
    ``experiments/calibrate.py`` (the measured side of the calibration
    loop), so all three time the identical program: a fresh subprocess with
    ``--xla_force_host_platform_device_count``, the plan's padded params,
    the (possibly ragged) sequence layout, warm-up, then ``iters`` timed
    jitted calls.  Raises on subprocess failure.
    """
    code = rf"""
import jax, jax.numpy as jnp, time
from repro.core import hmp
from repro.core.execplan import ExecPlan
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat(({devices},), ('model',))
eplan = ExecPlan(heads={tuple(eplan.heads)}, columns={tuple(eplan.columns)},
                 head_dim={eplan.head_dim}, d_model={eplan.d_model},
                 seq_shares={tuple(eplan.seq_shares)},
                 compute_backend={eplan.compute_backend!r},
                 transport={eplan.transport!r},
                 double_buffer={eplan.double_buffer})
p = hmp.init_layer_params(jax.random.PRNGKey(0), eplan.d_model,
                          eplan.num_heads, eplan.d_ff)
pp = eplan.pad_layer_params(p)
x = jax.random.normal(jax.random.PRNGKey(1), (1, {seq}, eplan.d_model))
xp = eplan.seq_layout({seq}).scatter(x)  # identity for dense layouts
for name, overlap in [('hmp', False), ('hmp_ring', True)]:
    f = jax.jit(lambda p, x, o=overlap: hmp.hmp_layer(p, x, mesh, overlap=o,
                                                      plan=eplan, seq={seq}))
    out = f(pp, xp); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range({iters}):
        out = f(pp, xp)
    jax.block_until_ready(out)
    print(f"{{name}},{{(time.perf_counter()-t0)/{iters}:.9f}}")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(
            f"execplan measurement subprocess failed:\n{proc.stderr[-2000:]}"
        )
    return {
        name: float(sec)
        for name, sec in (ln.split(",") for ln in proc.stdout.strip().splitlines())
    }


def kernel_fusion() -> Iterator[Row]:
    """fused_connective (1 HBM pass) vs unfused dropout+residual+LN."""
    from repro.kernels.ops import fused_connective
    from repro.kernels.ref import fused_connective_ref

    s, d = 2048, 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (s, d))
    res = jax.random.normal(jax.random.PRNGKey(1), (s, d))
    mask = jnp.ones((s, d))
    scale, bias = jnp.ones((d,)), jnp.zeros((d,))
    unfused = jax.jit(lambda *a: fused_connective_ref(*a, rate=0.0))
    t_ref = _time(unfused, x, res, mask, scale, bias)
    t_fused = _time(lambda *a: fused_connective(*a, rate=0.0), x, res, mask, scale, bias)
    yield ("micro/connective_unfused", t_ref, "jnp 3-pass")
    yield ("micro/connective_fused", t_fused, f"pallas 1-pass,{t_ref/t_fused:.2f}x")


def flash_vs_naive() -> Iterator[Row]:
    from repro.kernels.ops import flash_attention
    from repro.kernels.ref import flash_attention_ref

    b, h, s, hd = 1, 8, 1024, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, hd))
    t_naive = _time(jax.jit(lambda q, k, v: flash_attention_ref(q, k, v)), q, k, v, iters=3)
    t_flash = _time(lambda q, k, v: flash_attention(q, k, v), q, k, v, iters=3)
    yield ("micro/attention_naive", t_naive, "materialized scores")
    yield ("micro/attention_flash", t_flash,
           "pallas blocked (interpret on CPU; wins are on-TPU)")


def profiler_blocks() -> Iterator[Row]:
    """Galaxy Profiler measuring real block latencies (paper step 1)."""
    from repro.configs import get_config
    from repro.core.profiler import HostProfiler

    prof = HostProfiler(get_config("distilbert"), seq=128, iters=3)
    t = prof.measure_blocks(heads=12, columns=3072)
    yield ("micro/profiler_mha_full", t["mha"] * 1e6, "L(MHA,full,host)")
    yield ("micro/profiler_mlp_full", t["mlp"] * 1e6, "L(MLP,full,host)")
    yield ("micro/profiler_con_full", t["con"] * 1e6, "L(CON,full,host)")
    half = prof.measure_blocks(heads=6, columns=1536)
    yield ("micro/profiler_mha_half", half["mha"] * 1e6,
           f"half-partition,{t['mha']/half['mha']:.2f}x")


def hmp_schedules_multidevice() -> Iterator[Row]:
    """Per-layer wall time of hmp / hmp_ring / megatron / sp on 4 CPU
    devices (subprocess) — the real executable of the paper's comparison.
    CPU ppermute/collectives are emulation-grade; relative numbers only."""
    code = r"""
import jax, jax.numpy as jnp, time
from repro.core import hmp
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4,), ('model',))
p = hmp.init_layer_params(jax.random.PRNGKey(0), 256, 8, 1024)
x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 256))
for name, fn in hmp.SCHEDULES.items():
    f = jax.jit(lambda p, x, fn=fn: fn(p, x, mesh))
    out = f(p, x); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(10):
        out = f(p, x)
    jax.block_until_ready(out)
    print(f"{name},{(time.perf_counter()-t0)/10*1e6:.1f}")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        yield ("micro/hmp_schedules", float("nan"), "subprocess failed")
        return
    rows = dict(line.split(",") for line in proc.stdout.strip().splitlines())
    base = float(rows.get("megatron", "nan"))
    for name, us in rows.items():
        yield (f"micro/layer_{name}", float(us),
               f"vs megatron={base/float(us):.2f}x" if base == base else "")


def execplan_uneven() -> Iterator[Row]:
    """Measured vs simulated latency of the *same* uneven ExecPlan.

    The planner partitions a DistilBert layer over a 3:2:2:1 heterogeneous
    cluster; the resulting ExecPlan is (a) scored by the simulator (assigned
    workload and padded SPMD workload) and (b) executed for real through
    hmp / hmp_ring on 4 forced CPU devices.  Absolute scales differ (host
    CPU vs simulated Jetsons) — the point is one plan flowing through both.
    """
    import dataclasses

    from repro.configs import get_config
    from repro.core import costmodel, planner
    from repro.core.execplan import ExecPlan
    from repro.core.profiler import AnalyticProfiler
    from repro.core.simulator import simulate_execplan

    seq = 128
    cfg = dataclasses.replace(get_config("distilbert"), num_layers=1)
    caps = [3.0, 2.0, 2.0, 1.0]
    devices = [
        costmodel.DeviceSpec(f"edge{i}", flops=c * 7.1e9, mem_bw=4.0e9,
                             memory_budget=1.5e9)
        for i, c in enumerate(caps)
    ]
    link = costmodel.mbps(1000)
    prof = AnalyticProfiler(cfg, seq)
    pl = planner.plan(prof.model_profile(), prof.device_profiles(devices))
    if not pl.feasible:
        yield ("micro/execplan", float("nan"), f"plan infeasible:{pl.reason}")
        return
    eplan = ExecPlan.from_plan(pl, head_dim=cfg.head_dim, d_model=cfg.d_model)

    for name, padded, overlap in [
        ("sim/execplan_galaxy", False, False),
        ("sim/execplan_galaxy_overlap", False, True),
        ("sim/execplan_galaxy_overlap_padded", True, True),
    ]:
        r = simulate_execplan(eplan, cfg, devices, link, seq,
                              overlap=overlap, padded=padded)
        yield (name, r.latency * 1e6,
               f"simulated,{eplan.describe()}" if not padded else
               "simulated,every device runs max(units)")

    # measurement failures propagate: the CI bench-smoke --strict gate's
    # contract is "fails on exceptions", same as execplan_raggedsp below
    measured = measure_execplan_layers(eplan, seq)
    for name, sec in measured.items():
        yield (f"micro/execplan_{name}", sec * 1e6,
               f"measured,heads={list(eplan.heads)},cols={list(eplan.columns)}")


def execplan_raggedsp() -> Iterator[Row]:
    """Ragged sequence parallelism: equal vs bandwidth-aware seq split.

    A 3:2:2:1 DistilBert cluster with one slow link (100 Mbps against
    1 Gbps elsewhere): the planner solves uneven sequence tiles from
    capacity + link bandwidth (planner.sequence_partition), and the
    simulator scores both splits over the ragged ring
    (costmodel.t_ring_exchange).  The bandwidth-aware split keeps large
    tiles off the slow hop, so it must come out faster; the padded row
    shows what the SPMD pad-and-mask emulation of the same plan costs.
    The ragged plan is then executed for real through hmp / hmp_ring on 4
    forced CPU devices (measured, exactness asserted in tests).
    """
    import dataclasses

    from repro.configs import get_config
    from repro.core import costmodel
    from repro.core.execplan import ExecPlan
    from repro.core.profiler import AnalyticProfiler
    from repro.core.simulator import simulate_execplan

    seq = 128
    cfg = dataclasses.replace(get_config("distilbert"), num_layers=1)
    caps = [3.0, 2.0, 2.0, 1.0]
    devices = [
        costmodel.DeviceSpec(f"edge{i}", flops=c * 7.1e9, mem_bw=4.0e9,
                             memory_budget=1.5e9)
        for i, c in enumerate(caps)
    ]
    links = [costmodel.mbps(1000), costmodel.mbps(1000),
             costmodel.mbps(100), costmodel.mbps(1000)]
    prof = AnalyticProfiler(cfg, seq)
    ep_equal = ExecPlan.from_plan(prof.plan(devices), head_dim=cfg.head_dim,
                                  d_model=cfg.d_model)
    ep_aware = ExecPlan.from_plan(prof.plan(devices, links=links),
                                  head_dim=cfg.head_dim, d_model=cfg.d_model)

    r_eq = simulate_execplan(ep_equal, cfg, devices, links, seq, overlap=True)
    r_bw = simulate_execplan(ep_aware, cfg, devices, links, seq, overlap=True)
    r_pad = simulate_execplan(ep_aware, cfg, devices, links, seq,
                              overlap=True, padded=True)
    yield ("sim/raggedsp_equal_seq", r_eq.latency * 1e6,
           "simulated,slow link carries full tiles")
    yield ("sim/raggedsp_bandwidth_aware", r_bw.latency * 1e6,
           f"simulated,tiles={list(ep_aware.seq_tiles(seq))},"
           f"speedup={r_eq.latency / r_bw.latency:.2f}x")
    yield ("sim/raggedsp_bandwidth_aware_padded", r_pad.latency * 1e6,
           f"simulated,SPMD ships max tile,sp_waste="
           f"{ep_aware.seq_padding_waste():.1%}")

    measured = measure_execplan_layers(ep_aware, seq)
    for name, sec in measured.items():
        yield (f"micro/raggedsp_{name}", sec * 1e6,
               f"measured,tiles={list(ep_aware.seq_tiles(seq))},"
               f"padded rows per device={ep_aware.seq_tile(seq)}")


def execplan_overlap() -> Iterator[Row]:
    """Tile-granular overlap transports on an emulated slow-link cluster:
    padded vs bucketed vs bucketed + double-buffered ring exchanges.

    Same 3:2:2:1 DistilBert cluster as ``execplan_raggedsp`` with one
    100 Mbps link: the bandwidth-aware ragged plan runs ``hmp_ring`` for
    real on 4 forced CPU devices under all three transports, and the
    subprocess asserts the transports are *bitwise*-identical to each
    other and allclose to the unoverlapped sync schedule.  Forced host
    devices share one memory bus, so the wire cannot be throttled
    in-process; each variant's end-to-end latency is therefore *emulated*
    as measured compute wall + the cost model's wire time for the rows
    that transport actually ships (4 ring rotations per layer through
    ``costmodel.t_ring_exchange`` over the skewed links).  Double
    buffering issues the exchange before the GEMM that hides it, so its
    wire contributes only the overhang ``max(0, wire - wall)``.

    Gates (raise, not assert — they must also gate under -O):

    1. The bucketed schedule ships strictly fewer rows per rotation than
       padded transport on this plan (``RingSchedule.total_wire_rows``).
    2. Emulated bucketed+db latency lands closer to the simulator's
       ``sim/raggedsp_bandwidth_aware`` target than emulated padded
       transport does — the overlap transport closes the gap between the
       padded SPMD emulation and the plan the simulator priced.
    """
    import dataclasses

    from repro.configs import get_config
    from repro.core import costmodel
    from repro.core.execplan import ExecPlan
    from repro.core.profiler import AnalyticProfiler
    from repro.core.simulator import simulate_execplan

    seq = 128
    cfg = dataclasses.replace(get_config("distilbert"), num_layers=1)
    caps = [3.0, 2.0, 2.0, 1.0]
    devices = [
        costmodel.DeviceSpec(f"edge{i}", flops=c * 7.1e9, mem_bw=4.0e9,
                             memory_budget=1.5e9)
        for i, c in enumerate(caps)
    ]
    links = [costmodel.mbps(1000), costmodel.mbps(1000),
             costmodel.mbps(100), costmodel.mbps(1000)]
    prof = AnalyticProfiler(cfg, seq)
    ep = ExecPlan.from_plan(prof.plan(devices, links=links),
                            head_dim=cfg.head_dim, d_model=cfg.d_model)
    variants = {
        "padded": ep,
        "bucketed": ep.with_transport("bucketed"),
        "bucketed_db": ep.with_transport("bucketed", double_buffer=True),
    }

    # measured compute walls; outputs checked inside the subprocess
    code = rf"""
import jax, jax.numpy as jnp, numpy as np, time
from repro.core import hmp
from repro.core.execplan import ExecPlan
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4,), ('model',))
base = ExecPlan(heads={tuple(ep.heads)}, columns={tuple(ep.columns)},
                head_dim={ep.head_dim}, d_model={ep.d_model},
                seq_shares={tuple(ep.seq_shares)})
seq = {seq}
p = hmp.init_layer_params(jax.random.PRNGKey(0), base.d_model,
                          base.num_heads, base.d_ff)
pp = base.pad_layer_params(p)
x = jax.random.normal(jax.random.PRNGKey(1), (1, seq, base.d_model))
xp = base.seq_layout(seq).scatter(x)
outs = {{}}
sync = hmp.hmp_layer(pp, xp, mesh, overlap=False, plan=base, seq=seq)
for name, transport, db in [('padded', 'padded', False),
                            ('bucketed', 'bucketed', False),
                            ('bucketed_db', 'bucketed', True)]:
    ep = base.with_transport(transport, double_buffer=db)
    f = jax.jit(lambda p, x, e=ep: hmp.hmp_layer(p, x, mesh, overlap=True,
                                                 plan=e, seq=seq))
    y = f(pp, xp); jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(10):
        y = f(pp, xp)
    jax.block_until_ready(y)
    outs[name] = np.asarray(y)
    print(f"wall_{{name}},{{(time.perf_counter()-t0)/10:.9f}}")
err = np.abs(outs['padded'] - np.asarray(sync)).max()
if err >= 1e-4:
    raise RuntimeError(f"ring vs sync max err {{err:.3e}}")
for name in ('bucketed', 'bucketed_db'):
    if not np.array_equal(outs[name], outs['padded']):
        raise RuntimeError(f"{{name}} transport is not bitwise-equal to padded")
print(f"err_sync,{{err:.3e}}")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(f"overlap subprocess failed:\n{proc.stderr[-2000:]}")
    rows = dict(ln.split(",") for ln in proc.stdout.strip().splitlines())

    # modeled wire time of what each transport actually ships: 4 ring
    # rotations per layer (qkv/w1 allgather + wo/w2 reduce-scatter)
    row_bytes = cfg.d_model * costmodel.BYTES_ACT
    wire = {}
    for name, plan in variants.items():
        sched = plan.ring_schedule(seq)
        wire[name] = 4 * costmodel.t_ring_exchange(
            [int(b) * row_bytes for b in sched.buckets], links)
    sched_b = variants["bucketed"].ring_schedule(seq)
    if not sched_b.total_wire_rows() < sched_b.padded_wire_rows():
        raise RuntimeError(
            f"bucketed transport sheds nothing: ships "
            f"{sched_b.total_wire_rows()} of {sched_b.padded_wire_rows()} rows"
        )

    target = simulate_execplan(ep, cfg, devices, links, seq,
                               overlap=True).latency
    emulated = {}
    for name in variants:
        wall = float(rows[f"wall_{name}"])
        hidden = wall if name == "bucketed_db" else 0.0
        emulated[name] = wall + max(0.0, wire[name] - hidden)
    if not (abs(emulated["bucketed_db"] - target)
            < abs(emulated["padded"] - target)):
        raise RuntimeError(
            f"overlap transport does not close the gap to the simulator: "
            f"db={emulated['bucketed_db'] * 1e6:.0f}us "
            f"padded={emulated['padded'] * 1e6:.0f}us "
            f"target={target * 1e6:.0f}us"
        )

    yield ("sim/overlap_target", target * 1e6,
           "simulated,sim/raggedsp_bandwidth_aware (exact-bytes wire)")
    for name, plan in variants.items():
        sched = plan.ring_schedule(seq)
        yield (f"micro/overlap_{name}", emulated[name] * 1e6,
               f"emulated=wall+wire,wall={float(rows[f'wall_{name}']) * 1e6:.0f}us,"
               f"wire={wire[name] * 1e6:.0f}us,"
               f"wire_rows={sched.total_wire_rows()}/{sched.padded_wire_rows()},"
               f"bitwise-equal to padded")
    yield ("micro/overlap_err_sync", float(rows["err_sync"]),
           "ring vs unoverlapped sync schedule (atol 1e-4 gate)")


def execplan_padshed() -> Iterator[Row]:
    """Pad shedding: the pallas valid-length backend vs the padded-XLA
    oracle on the 3:2:2:1 uneven DistilBert plan.

    Three claims, measured:

    1. Per-device dense-block counts of the valid-length GEMMs (the
       kernel's own live-block counter) equal ``ceil(units[d]/block)`` —
       each device executes its *assigned* heads/columns, not
       ``max(units)``.  Block sizes map integrally onto units (one N block
       per head, 128 columns per MLP block) so counts convert to units
       exactly.
    2. The measured waste shed (1 - effective/padded unit-blocks) matches
       the bookkept ``ExecPlan.padding_waste()``.
    3. Backend outputs agree with the padded-XLA oracle (atol 1e-4) on the
       layer, prefill, and paged-decode paths (4 forced CPU devices), with
       wall times reported for both (interpret-mode pallas on a CPU host —
       the FLOPs counters, not the wall clock, are the shedding evidence;
       the MXU win needs a real TPU lowering).
    """
    import dataclasses

    from repro.configs import get_config
    from repro.core import costmodel, planner
    from repro.core.execplan import ExecPlan
    from repro.core.profiler import AnalyticProfiler
    from repro.kernels import ops

    seq = 128
    cfg = dataclasses.replace(get_config("distilbert"), num_layers=1)
    caps = [3.0, 2.0, 2.0, 1.0]
    devices = [
        costmodel.DeviceSpec(f"edge{i}", flops=c * 7.1e9, mem_bw=4.0e9,
                             memory_budget=1.5e9)
        for i, c in enumerate(caps)
    ]
    prof = AnalyticProfiler(cfg, seq)
    pl = planner.plan(prof.model_profile(), prof.device_profiles(devices))
    if not pl.feasible:
        yield ("padshed/plan", float("nan"), f"infeasible:{pl.reason}")
        return
    eplan = ExecPlan.from_plan(pl, head_dim=cfg.head_dim, d_model=cfg.d_model,
                               compute_backend="pallas")

    d, hd = cfg.d_model, cfg.head_dim
    ph, pc = eplan.pad_heads, eplan.pad_columns
    tile = seq // eplan.num_devices
    col_block = 128  # divides every planned column count below
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (tile, d))
    wqkv = jax.random.normal(key, (d, 3 * ph * hd)) * 0.05
    attn_in = jax.random.normal(key, (tile, ph * hd))
    wo = jax.random.normal(key, (ph * hd, d)) * 0.05
    h_in = jax.random.normal(key, (tile, pc))
    w1 = jax.random.normal(key, (d, pc)) * 0.05
    w2 = jax.random.normal(key, (pc, d)) * 0.05

    unit = costmodel.gemm_unit_flops(d, hd)
    eff_units = np.zeros(eplan.num_devices)
    pad_units = eplan.num_devices * (ph + pc)
    for dev, (heads, cols) in enumerate(zip(eplan.heads, eplan.columns)):
        # the same four per-shard GEMMs the executor traces, with this
        # device's valid counts; counts are measured by the kernel itself
        _, qkv_cnt = ops.gemm(x, wqkv, backend="pallas",
                              valid_n=heads * hd, seg_n=ph * hd,
                              block_m=tile, block_n=hd, block_k=d,
                              count_blocks=True)
        _, wo_cnt = ops.gemm(attn_in, wo, backend="pallas",
                             valid_k=heads * hd, block_m=tile,
                             block_n=d, block_k=hd, count_blocks=True)
        _, w1_cnt = ops.gemm(x, w1, backend="pallas", valid_n=cols,
                             block_m=tile, block_n=col_block, block_k=d,
                             count_blocks=True)
        _, w2_cnt = ops.gemm(h_in, w2, backend="pallas", valid_k=cols,
                             block_m=tile, block_n=d, block_k=col_block,
                             count_blocks=True)
        qkv_cnt, wo_cnt = int(qkv_cnt), int(wo_cnt)
        w1_cnt, w2_cnt = int(w1_cnt), int(w2_cnt)
        # acceptance gate: live blocks == ceil(units[d]/block), not
        # max(units) — raise (not assert: this must also gate under -O)
        expect = {
            "qkv": (qkv_cnt, 3 * heads),
            "wo": (wo_cnt, heads),
            "w1": (w1_cnt, -(-cols // col_block)),
            "w2": (w2_cnt, -(-cols // col_block)),
        }
        for gemm_name, (got, want) in expect.items():
            if got != want:
                raise RuntimeError(
                    f"dev{dev} {gemm_name}: measured {got} live blocks, "
                    f"expected ceil(units/block)={want}"
                )
        heads_meas = qkv_cnt // 3
        cols_meas = w1_cnt * col_block
        eff_units[dev] = heads_meas + cols_meas
        flops_eff = heads_meas * unit["head"] + cols_meas * unit["column"]
        flops_pad = ph * unit["head"] + pc * unit["column"]
        yield (f"padshed/blocks_dev{dev}",
               float(qkv_cnt + wo_cnt + w1_cnt + w2_cnt),
               f"heads={heads_meas}/{ph},cols={cols_meas}/{pc},"
               f"eff_flops={flops_eff / flops_pad:.0%}")

    shed = 1.0 - eff_units.sum() / pad_units
    waste = eplan.padding_waste()
    if abs(shed - waste) > 0.05 * waste:
        raise RuntimeError(
            f"measured waste shed {shed:.1%} drifts >5% from "
            f"ExecPlan.padding_waste()={waste:.1%}"
        )
    yield ("padshed/waste_shed", shed * 100,
           f"percent,vs ExecPlan.padding_waste={waste:.1%},"
           f"flops_shed={eplan.flops_shed():.1%}")

    # backend outputs vs the padded-XLA oracle on 4 forced CPU devices
    code = rf"""
import jax, jax.numpy as jnp, numpy as np, time
from repro.core import hmp
from repro.core.execplan import ExecPlan
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4,), ('model',))
ep = ExecPlan(heads={tuple(eplan.heads)}, columns={tuple(eplan.columns)},
              head_dim={cfg.head_dim}, d_model={cfg.d_model})
layers = [hmp.init_layer_params(jax.random.PRNGKey(0), ep.d_model,
                                ep.num_heads, ep.d_ff)]
seq, page = {seq}, 32
x = jax.random.normal(jax.random.PRNGKey(1), (1, seq, ep.d_model)) * 0.5
x_new = jax.random.normal(jax.random.PRNGKey(2), (1, 1, ep.d_model)) * 0.5
outs = {{}}
for name in ('xla', 'pallas'):
    b = ep.with_backend(name)
    pp = b.pad_layer_params(layers[0])
    f = jax.jit(lambda p, x, b=b: hmp.hmp_layer(p, x, mesh, overlap=True,
                                                plan=b, seq=seq))
    y = f(pp, x); jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(3):
        y = f(pp, x)
    jax.block_until_ready(y)
    wall = (time.perf_counter() - t0) / 3
    cache = hmp.make_kv_cache(1, seq + 4, 1, mesh, b)
    y_pre, cache = hmp.hmp_prefill(layers, x, mesh, cache, plan=b,
                                   overlap=True, seq=seq)
    pages = hmp.make_paged_kv_cache(6, page, 1, mesh, b)
    row = jnp.arange(1, 6, dtype=jnp.int32)
    y_pp, pages = hmp.hmp_prefill(layers, x, mesh, pages, plan=b,
                                  overlap=True, seq=seq, block_row=row)
    y_dec, pages = hmp.hmp_decode(layers, x_new, mesh, pages,
                                  jnp.asarray([seq]), plan=b,
                                  block_table=row[None])
    outs[name] = (np.asarray(y), np.asarray(y_pre), np.asarray(y_dec))
    print(f"wall_{{name}},{{wall:.9f}}")
for i, path in enumerate(('layer', 'prefill', 'decode_paged')):
    err = np.abs(outs['pallas'][i] - outs['xla'][i]).max()
    if err >= 1e-4:
        raise RuntimeError(f"{{path}}: pallas vs xla max err {{err:.3e}}")
    print(f"err_{{path}},{{err:.3e}}")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(f"padshed subprocess failed:\n{proc.stderr[-2000:]}")
    rows = dict(ln.split(",") for ln in proc.stdout.strip().splitlines())
    for name in ("xla", "pallas"):
        yield (f"micro/padshed_layer_{name}", float(rows[f"wall_{name}"]) * 1e6,
               "measured,interpret-mode pallas on CPU host" if name == "pallas"
               else "measured,padded dense oracle")
    for path in ("layer", "prefill", "decode_paged"):
        yield (f"padshed/allclose_{path}", float(rows[f"err_{path}"]),
               "max |pallas - xla| (atol 1e-4 gate)")


def continuous_vs_wave() -> Iterator[Row]:
    """Continuous batching vs wave scheduling on a skewed request mix.

    16 requests, equal 8-token prompts, output lengths skewed 32/4/4/4 — the
    wave scheduler's worst case: every wave drains at the pace of its longest
    request while the short requests' slots sit idle.  Continuous batching
    refills a slot the moment its request retires, so the decode batch stays
    full.  Reports tokens/sec and p50/p95 per-token latency per scheduler;
    greedy tokens are asserted identical (the engine-level contract).
    """
    import statistics

    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.obs import itl_seconds, ttft_percentiles
    from repro.serving import Request, ServingEngine

    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    from repro.serving import TransformerExecutor

    executor = TransformerExecutor(params, cfg)  # shared jit caches

    def requests():
        return [
            Request(uid=i, prompt=[1 + (i * 7 + j) % 200 for j in range(8)],
                    max_new_tokens=32 if i % 4 == 0 else 4)
            for i in range(16)
        ]

    def run_once(scheduler: str, timed: bool):
        eng = ServingEngine(executor=executor, max_batch=4, max_len=48,
                            scheduler=scheduler, page_size=8,
                            record_times=timed)
        for r in requests():
            eng.submit(r)
        t0 = time.perf_counter()
        done = eng.run()
        wall = time.perf_counter() - t0
        return done, wall, eng.stats

    results = {}
    outputs = {}
    done_by = {}
    for scheduler in ("wave", "continuous"):
        run_once(scheduler, timed=False)  # warm the jit caches
        done, wall, stats = run_once(scheduler, timed=True)
        toks = sum(len(r.output) for r in done)
        gaps = itl_seconds(done)  # the one shared ITL definition (repro.obs)
        results[scheduler] = (wall, toks, stats["decode_steps"], gaps)
        outputs[scheduler] = {r.uid: tuple(r.output) for r in done}
        done_by[scheduler] = done
    assert outputs["wave"] == outputs["continuous"], \
        "greedy tokens diverged between schedulers"

    wave_wall, wave_toks, wave_steps, wave_gaps = results["wave"]
    cont_wall, cont_toks, cont_steps, cont_gaps = results["continuous"]
    q = lambda xs, p: statistics.quantiles(xs, n=100)[p - 1] * 1e3  # ms

    yield ("serve/wave_us_per_token", wave_wall / wave_toks * 1e6,
           f"tokens/s={wave_toks / wave_wall:.1f},steps={wave_steps},"
           f"p50={q(wave_gaps, 50):.1f}ms,p95={q(wave_gaps, 95):.1f}ms")
    yield ("serve/continuous_us_per_token", cont_wall / cont_toks * 1e6,
           f"tokens/s={cont_toks / cont_wall:.1f},steps={cont_steps},"
           f"p50={q(cont_gaps, 50):.1f}ms,p95={q(cont_gaps, 95):.1f}ms,"
           f"speedup={wave_wall / cont_wall:.2f}x")
    for scheduler in ("wave", "continuous"):
        ttft = ttft_percentiles(done_by[scheduler])
        yield (f"serve/{scheduler}_ttft_p95", ttft["p95"] * 1e6,
               f"first-token latency,p50={ttft['p50'] * 1e3:.1f}ms,"
               f"n={ttft['n']}")


def prefix_sharing() -> Iterator[Row]:
    """Shared-prefix KV cache on a skewed request mix with a common
    256-token system prompt: tokens/sec + TTFT p50/p95, prefix cache on vs
    off (``serving/prefix_cache.py`` radix tree over refcounted pool pages).

    Acceptance gates (raise, not assert — they must also gate under -O):

    1. Suffix-only prefill: with the cache on, the engine's measured
       prefill token count equals ``sum(prompt_len - cached_prefix_len)``
       over all requests (prefix-hit tokens are *not* recomputed).
    2. Sharing is real: >= 1 physical page is referenced by >= 2 concurrent
       slots at some admission, with the pool's refcount algebra verified
       by ``PagedKVPool.check()`` on every sharing admission.
    3. Greedy tokens are identical cache on vs off (the engine contract).
    """
    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.obs import ttft_percentiles
    from repro.serving import Request, ServingEngine, TransformerExecutor

    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    executor = TransformerExecutor(params, cfg)  # shared jit caches

    prefix_len, tail_len = 256, 16
    system_prompt = [11 + (i * 13) % 150 for i in range(prefix_len)]

    def requests():
        return [
            Request(uid=i,
                    prompt=system_prompt
                    + [200 + (i * 7 + j) % 50 for j in range(tail_len)],
                    max_new_tokens=24 if i % 4 == 0 else 6)
            for i in range(12)
        ]

    def run_once(prefix_cache: bool, timed: bool):
        eng = ServingEngine(executor=executor, max_batch=4,
                            max_len=prefix_len + tail_len + 32,
                            scheduler="continuous", page_size=16,
                            prefix_cache=prefix_cache, record_times=timed)
        for r in requests():
            eng.submit(r)
        t0 = time.perf_counter()
        done = eng.run()
        wall = time.perf_counter() - t0
        return done, wall, eng.stats, eng.prefix_stats

    runs = {}
    for on in (False, True):
        run_once(on, timed=False)  # warm the jit caches
        runs[on] = run_once(on, timed=True)

    done_off, wall_off, stats_off, _ = runs[False]
    done_on, wall_on, stats_on, pstats = runs[True]
    if ({r.uid: tuple(r.output) for r in done_off}
            != {r.uid: tuple(r.output) for r in done_on}):
        raise RuntimeError("greedy tokens diverged between prefix cache on/off")
    total_prompt = sum(len(r.prompt) for r in done_on)
    cached = stats_on["cached_prefix_tokens"]
    if cached <= 0:
        raise RuntimeError("prefix cache never hit on a shared system prompt")
    if stats_on["prefill_tokens"] + cached != total_prompt:
        raise RuntimeError(
            f"suffix-only prefill broken: computed {stats_on['prefill_tokens']}"
            f" + cached {cached} != prompt tokens {total_prompt}"
        )
    if stats_on["peak_shared_pages"] < 1:
        raise RuntimeError("no physical page was shared across >=2 live slots")

    for on, label in ((False, "prefix_off"), (True, "prefix_on")):
        done, wall, stats, _ = runs[on]
        toks = sum(len(r.output) for r in done)
        ttft = ttft_percentiles(done)
        extra = ""
        if on:
            extra = (f",hit_rate={pstats['hit_rate']:.0%},"
                     f"cached_tokens={cached},"
                     f"shared_pages={stats['peak_shared_pages']},"
                     f"prefill={stats['prefill_tokens']}/{total_prompt},"
                     f"speedup={wall_off / wall_on:.2f}x")
        yield (f"serve/{label}_us_per_token", wall / toks * 1e6,
               f"tokens/s={toks / wall:.1f},"
               f"ttft_p50={ttft['p50'] * 1e3:.1f}ms,"
               f"ttft_p95={ttft['p95'] * 1e3:.1f}ms{extra}")


def continuous_vs_wave_galaxy() -> Iterator[Row]:
    """Continuous vs wave through the Galaxy HMP executor under an uneven
    3:2:2:1 ExecPlan on 4 forced CPU devices (subprocess) — the same skewed
    mix, decoded through the paper-exact schedule against the head-sharded
    page pool."""
    code = r"""
import jax, jax.numpy as jnp, time
from repro.core import hmp, planner
from repro.core.execplan import ExecPlan
from repro.core.planner import DeviceProfile, ModelProfile
from repro.launch.mesh import make_mesh_compat
from repro.serving import GalaxyHMPExecutor, Request, ServingEngine

caps = [3.0, 2.0, 2.0, 1.0]
model = ModelProfile('bench', 2, 16, 256, 1e6, 2e6)
devs = [DeviceProfile(f'd{i}', c, 1e12) for i, c in enumerate(caps)]
ep = ExecPlan.from_plan(planner.plan(model, devs), head_dim=8, d_model=128)
mesh = make_mesh_compat((4,), ('model',))
layers = hmp.init_stack_params(jax.random.PRNGKey(0), 2, 128, 16, 256)
emb = jax.random.normal(jax.random.PRNGKey(7), (300, 128)) * 0.5
exe = GalaxyHMPExecutor(layers, emb, ep, mesh, overlap=True)

def run(scheduler):
    eng = ServingEngine(executor=exe, max_batch=4, max_len=48,
                        scheduler=scheduler, page_size=8)
    for i in range(8):
        eng.submit(Request(uid=i, prompt=[1 + (i + j) % 250 for j in range(12)],
                           max_new_tokens=24 if i % 4 == 0 else 4))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    return wall, sum(len(r.output) for r in done), {r.uid: tuple(r.output) for r in done}

outs = {}
for scheduler in ('wave', 'continuous'):
    run(scheduler)  # warm
    wall, toks, out = run(scheduler)
    outs[scheduler] = out
    print(f"{scheduler},{wall / toks * 1e6:.1f},{toks / wall:.1f}")
assert outs['wave'] == outs['continuous'], 'greedy tokens diverged'
print(f"page_bytes,{ep.kv_page_bytes(8)},{ep.describe()}")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(f"galaxy continuous bench failed:\n{proc.stderr[-2000:]}")
    rows = {}
    for line in proc.stdout.strip().splitlines():
        name, us, derived = line.split(",", 2)
        rows[name] = (float(us), derived)
    speed = rows["wave"][0] / rows["continuous"][0]
    yield ("serve/galaxy_wave_us_per_token", rows["wave"][0],
           f"tokens/s={rows['wave'][1]}")
    yield ("serve/galaxy_continuous_us_per_token", rows["continuous"][0],
           f"tokens/s={rows['continuous'][1]},speedup={speed:.2f}x")
    yield ("serve/galaxy_kv_page_bytes", rows["page_bytes"][0],
           rows["page_bytes"][1])


def spec_decode() -> Iterator[Row]:
    """Speculative decoding (``serving/spec.py``) in the batch-1 latency
    regime: a 2-layer draft proposes k=4 tokens, a 12-layer target verifies
    all of them in one 5-row chunk prefill over the paged cache.

    The model pair is constructed so the draft genuinely approximates the
    target: the target's first two layer groups *are* the draft's (same
    embedding, tied unembedding), and its remaining ten layers are random
    weights scaled by eps=0.2 — a small residual on top of the shared
    trunk, yielding a high-but-imperfect acceptance rate (rejections and
    all-accept rounds both occur).

    Acceptance gates (raise, not assert — they must also gate under -O):

    1. Greedy tokens are bitwise identical spec on vs off (the engine
       contract: verification pins the sequential argmax path).
    2. Speculation actually accepted drafts (acceptance rate > 0) and at
       least one round rejected a draft (the rollback path ran).
    3. Tokens/sec improves with speculation on.
    """
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.serving import Request, ServingEngine, TransformerExecutor

    draft_cfg = reduced(get_config("qwen1.5-0.5b"))
    target_cfg = dataclasses.replace(
        reduced(get_config("codeqwen1.5-7b")),
        num_layers=12, tie_embeddings=True,
    )
    draft_params = init_params(draft_cfg, jax.random.PRNGKey(0))
    target_params = init_params(target_cfg, jax.random.PRNGKey(1))
    eps = 0.2
    target_params = {
        "embed": draft_params["embed"],
        "final_norm": draft_params["final_norm"],
        "tail": target_params["tail"],
        "groups": jax.tree.map(
            lambda d, t: jnp.concatenate(
                [d, t[draft_cfg.num_layers:] * eps], axis=0)
            if jnp.issubdtype(t.dtype, jnp.floating) else t,
            draft_params["groups"], target_params["groups"],
        ),
    }
    target_exec = TransformerExecutor(target_params, target_cfg)
    draft_exec = TransformerExecutor(draft_params, draft_cfg)

    def requests():  # skewed prompt lengths, batch-1 latency mix
        return [
            Request(uid=i,
                    prompt=[1 + (i * 7 + j) % 200
                            for j in range(24 if i % 3 == 0 else 8)],
                    max_new_tokens=32 if i % 2 == 0 else 12)
            for i in range(6)
        ]

    def run_once(spec: bool):
        eng = ServingEngine(executor=target_exec, max_batch=1, max_len=64,
                            scheduler="continuous", page_size=8,
                            draft_executor=draft_exec if spec else None,
                            spec_k=4 if spec else None)
        for r in requests():
            eng.submit(r)
        t0 = time.perf_counter()
        done = eng.run()
        wall = time.perf_counter() - t0
        return done, wall, eng.stats

    runs = {}
    for spec in (False, True):
        run_once(spec)  # warm the jit caches
        runs[spec] = run_once(spec)

    done_off, wall_off, stats_off = runs[False]
    done_on, wall_on, stats_on = runs[True]
    if ({r.uid: tuple(r.output) for r in done_off}
            != {r.uid: tuple(r.output) for r in done_on}):
        raise RuntimeError("greedy tokens diverged between spec on/off")
    if stats_on["spec_accepted"] <= 0:
        raise RuntimeError("speculation never accepted a draft token")
    if stats_on["spec_accepted"] >= stats_on["spec_proposed"]:
        raise RuntimeError("no draft was ever rejected: rollback never ran")
    if wall_on >= wall_off:
        raise RuntimeError(
            f"speculation did not improve tokens/sec "
            f"({wall_off:.3f}s off vs {wall_on:.3f}s on)"
        )

    toks_off = sum(len(r.output) for r in done_off)
    toks_on = sum(len(r.output) for r in done_on)
    yield ("serve/spec_off_us_per_token", wall_off / toks_off * 1e6,
           f"tokens/s={toks_off / wall_off:.1f},"
           f"decode_steps={stats_off['decode_steps']}")
    counts = ",".join(
        f"{k}:{v}" for k, v in sorted(stats_on["spec_accept_counts"].items()))
    yield ("serve/spec_on_us_per_token", wall_on / toks_on * 1e6,
           f"tokens/s={toks_on / wall_on:.1f},"
           f"speedup={wall_off / wall_on:.2f}x,"
           f"acceptance={stats_on['spec_acceptance']:.0%},"
           f"rounds={stats_on['spec_steps']},"
           f"accept_counts={counts}")


def serving_telemetry() -> Iterator[Row]:
    """Serving observability (``repro.obs``): what telemetry costs and
    whether the exported trace is faithful.

    Acceptance gates (raise, not assert — they must also gate under -O):

    1. Structural zero overhead when disabled: a serve run with no tracer
       and ``record_times=False`` executes **zero** ``Tracer`` calls and
       zero ``Histogram.observe`` calls (counted by patching the classes)
       — the disabled path is a per-token no-op by construction, not
       merely "fast enough on this host".
    2. Greedy tokens are bitwise identical telemetry on vs off (the
       engine contract — tracing must not perturb the RNG path).
    3. Trace fidelity: the Chrome trace exports with no open spans and the
       per-request phase spans cover >= 95 % of every request's
       submit→retire wall time.

    With ``TELEMETRY_ARTIFACT_DIR`` set (the CI bench-smoke job), writes
    ``telemetry-trace.json`` + ``telemetry-metrics.json`` there for
    artifact upload.
    """
    import json as _json

    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.obs import Tracer
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    from repro.serving import Request, ServingEngine, TransformerExecutor

    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    executor = TransformerExecutor(params, cfg)  # shared jit caches

    def requests():
        return [
            Request(uid=i, prompt=[1 + (i * 7 + j) % 200 for j in range(8)],
                    max_new_tokens=24 if i % 4 == 0 else 6)
            for i in range(8)
        ]

    def run_once(tracer=None, record_times=False):
        eng = ServingEngine(executor=executor, max_batch=4, max_len=48,
                            scheduler="continuous", page_size=8,
                            record_times=record_times, tracer=tracer)
        for r in requests():
            eng.submit(r)
        t0 = time.perf_counter()
        done = eng.run()
        wall = time.perf_counter() - t0
        return eng, done, wall

    run_once()  # warm the jit caches

    # gate 1: count every tracer / histogram-observe invocation while the
    # disabled engine serves the full mix
    calls = {"n": 0}

    def counting(fn):
        def wrapped(*a, **k):
            calls["n"] += 1
            return fn(*a, **k)
        return wrapped

    patched = [(obs_trace.Tracer, m) for m in ("begin", "end", "instant")]
    patched.append((obs_metrics.Histogram, "observe"))
    originals = [(cls, name, getattr(cls, name)) for cls, name in patched]
    for cls, name, orig in originals:
        setattr(cls, name, counting(orig))
    try:
        _, done_off, wall_off = run_once()
    finally:
        for cls, name, orig in originals:
            setattr(cls, name, orig)
    if calls["n"] != 0:
        raise RuntimeError(
            f"disabled telemetry executed {calls['n']} tracer/histogram "
            f"calls — the off path must be a structural no-op"
        )

    tracer = Tracer()
    eng_on, done_on, wall_on = run_once(tracer=tracer, record_times=True)
    if ({r.uid: tuple(r.output) for r in done_off}
            != {r.uid: tuple(r.output) for r in done_on}):
        raise RuntimeError("greedy tokens diverged telemetry on vs off")

    obj = tracer.to_json()  # raises if any span is still open
    spans = [e for e in obj["traceEvents"] if e.get("ph") == "X"]
    names = {e["tid"]: e["args"]["name"] for e in obj["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "thread_name"}
    coverage = []
    for r in done_on:
        tid = next(t for t, n in names.items() if n == f"req {r.uid}")
        track = [e for e in spans if e["tid"] == tid]
        lo = min(e["ts"] for e in track)
        hi = max(e["ts"] + e["dur"] for e in track)
        coverage.append(sum(e["dur"] for e in track) / (hi - lo) if hi > lo
                        else 1.0)
    min_cov = min(coverage)
    if min_cov < 0.95:
        raise RuntimeError(
            f"request phase spans cover only {min_cov:.1%} of submit->retire"
        )

    out_dir = os.environ.get("TELEMETRY_ARTIFACT_DIR")
    if out_dir:
        with open(os.path.join(out_dir, "telemetry-trace.json"), "w") as f:
            _json.dump(obj, f)
        with open(os.path.join(out_dir, "telemetry-metrics.json"), "w") as f:
            _json.dump(eng_on.metrics.snapshot(), f, indent=2, default=float)

    toks_off = sum(len(r.output) for r in done_off)
    toks_on = sum(len(r.output) for r in done_on)
    snap = eng_on.metrics.snapshot()
    yield ("serve/telemetry_off_us_per_token", wall_off / toks_off * 1e6,
           "no tracer: 0 telemetry calls per token (structurally gated)")
    yield ("serve/telemetry_on_us_per_token", wall_on / toks_on * 1e6,
           f"overhead={wall_on / wall_off - 1:+.1%},"
           f"trace_events={len(spans)},"
           f"min_span_coverage={min_cov:.1%},"
           f"ttft_p50={snap['histograms']['ttft_s']['p50'] * 1e3:.1f}ms")


ALL = [kernel_fusion, flash_vs_naive, profiler_blocks,
       hmp_schedules_multidevice, execplan_uneven, execplan_raggedsp,
       execplan_overlap, execplan_padshed, continuous_vs_wave,
       continuous_vs_wave_galaxy, prefix_sharing, spec_decode,
       serving_telemetry]
