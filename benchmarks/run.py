"""Benchmark harness — one function per paper table/figure + real host
microbenchmarks + the roofline summary of completed dry-runs.

Prints ``name,us_per_call,derived`` CSV (one row per measurement).
"""
from __future__ import annotations

import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so `from benchmarks import ...` works when run as a script
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _emit(name, us, derived):
    us_s = "nan" if (isinstance(us, float) and math.isnan(us)) else f"{us:.1f}"
    print(f"{name},{us_s},{derived}")


def roofline_summary():
    """Summarize any dry-run JSONs already produced (experiments/dryrun/)."""
    import json
    import glob

    pat = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun", "*.json")
    for path in sorted(glob.glob(pat)):
        with open(path) as f:
            r = json.load(f)
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("hmp_sequence_parallel") is False:
            name += "/tp_only"
        yield (
            name,
            r["roofline_step_s"] * 1e6,
            f"bottleneck={r['bottleneck']},mfu={r['roofline_mfu']:.3f},"
            f"useful={r['useful_flops_ratio']:.2f}",
        )


def main() -> None:
    from benchmarks import microbench, paper_tables

    print("name,us_per_call,derived")
    for fn in paper_tables.ALL:
        for row in fn():
            _emit(*row)
    for fn in microbench.ALL:
        try:
            for row in fn():
                _emit(*row)
        except Exception as e:  # noqa: BLE001 — benches report, not crash
            _emit(f"micro/{fn.__name__}", float("nan"), f"error:{type(e).__name__}")
    for row in roofline_summary():
        _emit(*row)


if __name__ == "__main__":
    main()
