"""Benchmark harness — one function per paper table/figure + real host
microbenchmarks + the roofline summary of completed dry-runs.

Prints ``name,us_per_call,derived`` CSV (one row per measurement).

CLI (used by the CI ``bench-smoke`` job):
  --only a,b   run only the named microbench functions (skips paper tables
               and the roofline summary)
  --json PATH  also write {"rows": [row objects], "errors": [strings]}
  --strict     exit nonzero if any benchmark raised (timings never fail)
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so `from benchmarks import ...` works when run as a script
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def ttft_percentiles(requests) -> dict:
    """Time-to-first-token percentiles (seconds) from the engine's
    ``record_times`` stamps.  Thin wrapper kept for callers of the historic
    location — the one shared implementation lives in ``repro.obs``
    (``obs/metrics.py``), next to the registry's histogram percentiles."""
    from repro.obs import ttft_percentiles as _ttft

    return _ttft(requests)


def _emit(rows, name, us, derived):
    us_s = "nan" if (isinstance(us, float) and math.isnan(us)) else f"{us:.1f}"
    print(f"{name},{us_s},{derived}")
    rows.append({"name": name, "us_per_call": None if us_s == "nan" else float(us),
                 "derived": derived})


def roofline_summary():
    """Summarize any dry-run JSONs already produced (experiments/dryrun/)."""
    import glob

    pat = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun", "*.json")
    for path in sorted(glob.glob(pat)):
        with open(path) as f:
            r = json.load(f)
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("hmp_sequence_parallel") is False:
            name += "/tp_only"
        yield (
            name,
            r["roofline_step_s"] * 1e6,
            f"bottleneck={r['bottleneck']},mfu={r['roofline_mfu']:.3f},"
            f"useful={r['useful_flops_ratio']:.2f}",
        )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="",
                    help="comma-separated microbench function names")
    ap.add_argument("--json", default="", help="write rows as JSON to this path")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero if any benchmark raised")
    args = ap.parse_args(argv)

    from benchmarks import microbench, paper_tables

    only = {n for n in args.only.split(",") if n}
    unknown = only - {fn.__name__ for fn in microbench.ALL}
    if unknown:
        ap.error(f"unknown microbench name(s): {sorted(unknown)}")

    rows: list = []
    errors: list = []
    print("name,us_per_call,derived")
    if not only:
        for fn in paper_tables.ALL:
            for row in fn():
                _emit(rows, *row)
    for fn in microbench.ALL:
        if only and fn.__name__ not in only:
            continue
        try:
            for row in fn():
                _emit(rows, *row)
        except Exception as e:  # noqa: BLE001 — benches report, not crash
            errors.append(f"{fn.__name__}: {type(e).__name__}: {e}")
            _emit(rows, f"micro/{fn.__name__}", float("nan"),
                  f"error:{type(e).__name__}")
    if not only:
        for row in roofline_summary():
            _emit(rows, *row)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "errors": errors}, f, indent=2)
    if errors:
        print(f"{len(errors)} benchmark(s) raised:", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        if args.strict:
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
