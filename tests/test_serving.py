"""Serving engine: wave scheduling, greedy determinism, cache bytes."""
import jax
import jax.numpy as jnp

from repro.models import apply_model, init_params
from repro.serving import Request, ServingEngine, cache_bytes, make_cache

from helpers import smoke_cfg


def test_greedy_engine_matches_manual_decode():
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = list(range(1, 9))
    n_new = 5

    # manual reference: prefill + argmax decode
    toks = jnp.asarray([prompt], jnp.int32)
    cache = make_cache(cfg, 1, len(prompt) + n_new)
    logits, cache, _ = apply_model(params, cfg, mode="prefill", cache=cache, tokens=toks)
    out_ref = []
    last = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    for t in range(n_new):
        out_ref.append(int(last[0]))
        if t == n_new - 1:
            break
        idx = jnp.int32(len(prompt) + t)
        logits, cache, _ = apply_model(
            params, cfg, mode="decode", cache=cache, cache_index=idx,
            positions=jnp.full((1, 1), idx, jnp.int32), tokens=last[:, None],
        )
        last = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

    eng = ServingEngine(params, cfg, max_batch=4, max_len=32)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=n_new))
    done = eng.run()
    assert done[0].output == out_ref


def test_wave_bucketing_by_length():
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(params, cfg, max_batch=8, max_len=32)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=[1] * 8, max_new_tokens=2))
    for i in range(2):
        eng.submit(Request(uid=10 + i, prompt=[1] * 4, max_new_tokens=2))
    done = eng.run()
    assert len(done) == 5 and all(r.done for r in done)
    assert all(len(r.output) == 2 for r in done)


def test_eos_stops_early():
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(params, cfg, max_batch=2, max_len=64)
    # find the greedy first token, then use it as "EOS"
    eng.submit(Request(uid=0, prompt=[1, 2, 3, 4], max_new_tokens=8))
    first = eng.run()[0].output[0]
    eng.submit(Request(uid=1, prompt=[1, 2, 3, 4], max_new_tokens=8, eos_id=first))
    r = eng.run()[0]
    assert r.output == [first]


def test_scheduler_auto_uses_continuous_batching():
    """The default executor implements the paged protocol, so "auto"
    resolves to continuous batching — and still matches the wave path's
    greedy tokens while spending fewer decode steps on a skewed mix."""
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    def run(scheduler):
        eng = ServingEngine(params, cfg, max_batch=2, max_len=32,
                            scheduler=scheduler, page_size=4)
        for i in range(6):
            eng.submit(Request(uid=i, prompt=[1 + i] * 8,
                               max_new_tokens=12 if i % 3 == 0 else 2))
        return {r.uid: r.output for r in eng.run()}, eng.stats

    auto, auto_stats = run("auto")
    wave, wave_stats = run("wave")
    assert auto == wave
    assert auto_stats["decode_steps"] < wave_stats["decode_steps"]


def test_zero_budget_request_emits_nothing_on_both_schedulers():
    """max_new_tokens=0, a prompt filling max_len, or a prompt *exceeding*
    max_len all yield an empty output on both paths (never reaching the
    executor), even when batched with live wave-mates."""
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    def run(scheduler):
        eng = ServingEngine(params, cfg, max_batch=4, max_len=16,
                            scheduler=scheduler, page_size=4)
        eng.submit(Request(uid=0, prompt=[1] * 8, max_new_tokens=0))
        eng.submit(Request(uid=1, prompt=[2] * 8, max_new_tokens=4))
        eng.submit(Request(uid=2, prompt=list(range(1, 17)), max_new_tokens=4))
        eng.submit(Request(uid=3, prompt=list(range(1, 21)), max_new_tokens=4))
        return {r.uid: r.output for r in eng.run()}

    wave = run("wave")
    cont = run("continuous")
    assert wave == cont
    assert wave[0] == [] and wave[2] == [] and wave[3] == []
    assert len(wave[1]) == 4


def test_continuous_records_token_times():
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(params, cfg, max_batch=2, max_len=32,
                        record_times=True)
    eng.submit(Request(uid=0, prompt=[1, 2, 3, 4], max_new_tokens=4))
    r = eng.run()[0]
    assert len(r.token_times) == len(r.output)
    assert all(b >= a for a, b in zip(r.token_times, r.token_times[1:]))


def test_cache_bytes_scaling():
    cfg = smoke_cfg("qwen1.5-0.5b")
    b1 = cache_bytes(cfg, 1, 128)
    b2 = cache_bytes(cfg, 2, 128)
    assert b2 == 2 * b1
    import dataclasses
    wcfg = dataclasses.replace(cfg, window=16)
    assert cache_bytes(wcfg, 1, 4096) < cache_bytes(cfg, 1, 4096) / 10
