"""Serving engine: wave scheduling, greedy determinism, cache bytes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import apply_model, init_params
from repro.serving import Request, SamplerConfig, ServingEngine, cache_bytes, make_cache
from repro.serving.sampler import sample

from helpers import smoke_cfg


def test_greedy_engine_matches_manual_decode():
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = list(range(1, 9))
    n_new = 5

    # manual reference: prefill + argmax decode
    toks = jnp.asarray([prompt], jnp.int32)
    cache = make_cache(cfg, 1, len(prompt) + n_new)
    logits, cache, _ = apply_model(params, cfg, mode="prefill", cache=cache, tokens=toks)
    out_ref = []
    last = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    for t in range(n_new):
        out_ref.append(int(last[0]))
        if t == n_new - 1:
            break
        idx = jnp.int32(len(prompt) + t)
        logits, cache, _ = apply_model(
            params, cfg, mode="decode", cache=cache, cache_index=idx,
            positions=jnp.full((1, 1), idx, jnp.int32), tokens=last[:, None],
        )
        last = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

    eng = ServingEngine(params, cfg, max_batch=4, max_len=32)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=n_new))
    done = eng.run()
    assert done[0].output == out_ref


def test_wave_bucketing_by_length():
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(params, cfg, max_batch=8, max_len=32)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=[1] * 8, max_new_tokens=2))
    for i in range(2):
        eng.submit(Request(uid=10 + i, prompt=[1] * 4, max_new_tokens=2))
    done = eng.run()
    assert len(done) == 5 and all(r.done for r in done)
    assert all(len(r.output) == 2 for r in done)


def test_eos_stops_early():
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(params, cfg, max_batch=2, max_len=64)
    # find the greedy first token, then use it as "EOS"
    eng.submit(Request(uid=0, prompt=[1, 2, 3, 4], max_new_tokens=8))
    first = eng.run()[0].output[0]
    eng.submit(Request(uid=1, prompt=[1, 2, 3, 4], max_new_tokens=8, eos_id=first))
    r = eng.run()[0]
    assert r.output == [first]


def test_samplers():
    logits = jnp.asarray([[0.0, 5.0, 1.0]])
    assert int(sample(logits, jax.random.PRNGKey(0), SamplerConfig())[0]) == 1
    t = sample(logits, jax.random.PRNGKey(0), SamplerConfig(temperature=1.0, top_k=2))
    assert int(t[0]) in (1, 2)


def test_cache_bytes_scaling():
    cfg = smoke_cfg("qwen1.5-0.5b")
    b1 = cache_bytes(cfg, 1, 128)
    b2 = cache_bytes(cfg, 2, 128)
    assert b2 == 2 * b1
    import dataclasses
    wcfg = dataclasses.replace(cfg, window=16)
    assert cache_bytes(wcfg, 1, 4096) < cache_bytes(cfg, 1, 4096) / 10
