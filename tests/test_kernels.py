"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles in kernels/ref.py (interpret mode executes the kernel body)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_connective import fused_connective
from repro.kernels.tiled_gemm import tiled_gemm

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize(
    "b,h,hkv,sq,sk,hd,causal,window",
    [
        (1, 4, 4, 128, 128, 64, True, 0),
        (2, 8, 2, 128, 128, 64, True, 0),       # GQA 4:1
        (1, 4, 1, 128, 256, 32, True, 0),       # MQA, right-aligned decode-ish
        (1, 4, 4, 128, 128, 64, True, 32),      # sliding window
        (1, 2, 2, 64, 128, 128, False, 0),      # cross-attn (no mask)
        (1, 16, 2, 256, 256, 64, True, 64),
    ],
)
def test_flash_attention_sweep(b, h, hkv, sq, sk, hd, causal, window):
    q = jax.random.normal(KEY, (b, h, sq, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, sk, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, sk, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), atol=2e-6)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 5e-4), (jnp.bfloat16, 0.25)])
@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (256, 512, 384), (512, 128, 256)])
def test_tiled_gemm_sweep(m, k, n, dtype, atol):
    x = jax.random.normal(KEY, (m, k)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)).astype(dtype)
    out = tiled_gemm(x, w, block_m=128, block_n=128, block_k=128, interpret=True)
    expected = ref.tiled_gemm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32), atol=atol
    )


@pytest.mark.parametrize("s,d", [(256, 128), (512, 256), (128, 512)])
@pytest.mark.parametrize("rate", [0.0, 0.1])
def test_fused_connective_sweep(s, d, rate):
    x = jax.random.normal(KEY, (s, d), jnp.float32)
    res = jax.random.normal(jax.random.PRNGKey(1), (s, d), jnp.float32)
    mask = (jax.random.uniform(jax.random.PRNGKey(2), (s, d)) > rate).astype(jnp.float32)
    scale = jnp.ones((d,)) * 1.3
    bias = jnp.zeros((d,)) + 0.05
    out = fused_connective(x, res, mask, scale, bias, rate=rate, block_s=128,
                           interpret=True)
    expected = ref.fused_connective_ref(x, res, mask, scale, bias, rate=rate)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), atol=2e-5)


def test_kernel_shape_errors_are_valueerrors():
    """Bad tilings raise ValueError naming shapes/blocks (not a bare assert
    that vanishes under ``python -O``)."""
    x = jnp.zeros((100, 64))
    w = jnp.zeros((64, 96))
    with pytest.raises(ValueError, match="block_m=48"):
        tiled_gemm(x, w, block_m=48, block_n=32, block_k=32, interpret=True)
    with pytest.raises(ValueError, match="contraction mismatch"):
        tiled_gemm(jnp.zeros((64, 32)), jnp.zeros((48, 96)), interpret=True)
    q = jnp.zeros((1, 2, 100, 64))
    with pytest.raises(ValueError, match="block_q=32"):
        flash_attention(q, q, q, block_q=32, block_k=50, interpret=True)
    with pytest.raises(ValueError, match="block_s"):
        fused_connective(jnp.zeros((100, 8)), jnp.zeros((100, 8)),
                         jnp.zeros((100, 8)), jnp.ones(8), jnp.zeros(8),
                         block_s=32, interpret=True)
    from repro.kernels.tiled_gemm import tiled_gemm_valid

    with pytest.raises(ValueError, match="seg_m"):
        tiled_gemm_valid(x, w, seg_m=48, interpret=True)


def test_valid_gemm_matches_dense_when_fully_valid():
    """With no valid counts the valid-length kernel is the dense GEMM."""
    x = jax.random.normal(KEY, (64, 96), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (96, 128), jnp.float32)
    from repro.kernels.tiled_gemm import tiled_gemm_valid

    out = tiled_gemm_valid(x, w, block_m=32, block_n=32, block_k=32,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.tiled_gemm_ref(x, w)),
                               atol=5e-4)


def test_ops_gemm_backend_dispatch():
    """ops.gemm: xla == pallas on clean (zero-padded) operands; batched
    inputs fold into M segments; unknown backends are rejected."""
    x = jax.random.normal(KEY, (2, 8, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 12), jnp.float32)
    w = w.at[:, 9:].set(0)  # pad columns zero, as ExecPlan materializes
    dense = ops.gemm(x, w, backend="xla")
    shed = ops.gemm(x, w, backend="pallas", valid_n=9, block_n=3)
    np.testing.assert_allclose(np.asarray(shed), np.asarray(dense), atol=1e-5)
    with pytest.raises(ValueError, match="backend"):
        ops.gemm(x, w, backend="cuda")
    with pytest.raises(ValueError, match="count_blocks"):
        ops.gemm(x, w, backend="xla", count_blocks=True)


def test_ops_wrappers_jit():
    """The public ops wrappers are jit-compatible on this backend."""
    q = jax.random.normal(KEY, (1, 2, 128, 64))
    out = ops.flash_attention(q, q, q)
    assert out.shape == q.shape
    x = jax.random.normal(KEY, (256, 256))
    assert ops.tiled_gemm(x, x).shape == (256, 256)


@pytest.mark.parametrize(
    "b,s,w,bs,bw",
    [(2, 128, 64, 32, 32), (1, 256, 128, 64, 128), (3, 64, 96, 64, 32)],
)
def test_rglru_scan_kernel_sweep(b, s, w, bs, bw):
    from repro.kernels.rglru_scan import rglru_scan_kernel

    a = jax.random.uniform(KEY, (b, s, w), minval=0.5, maxval=0.99)
    bb = jax.random.normal(jax.random.PRNGKey(1), (b, s, w))
    h0 = jax.random.normal(jax.random.PRNGKey(2), (b, w))
    hs, hl = rglru_scan_kernel(a, bb, h0, block_s=bs, block_w=bw, interpret=True)
    rs, rl = ref.rglru_scan_ref(a, bb, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(rs), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(rl), atol=1e-5)


def test_rglru_scan_kernel_matches_model_scan():
    """The Pallas kernel agrees with the model's associative_scan path."""
    from repro.kernels.rglru_scan import rglru_scan_kernel
    from repro.models.rglru import rglru_scan as assoc_scan

    b, s, w = 2, 64, 32
    a = jax.random.uniform(KEY, (b, s, w), minval=0.3, maxval=0.999)
    bb = jax.random.normal(jax.random.PRNGKey(1), (b, s, w))
    h0 = jax.random.normal(jax.random.PRNGKey(2), (b, w))
    hs_k, hl_k = rglru_scan_kernel(a, bb, h0, block_s=32, block_w=32, interpret=True)
    hs_a, hl_a = assoc_scan(a.astype(jnp.float32), bb.astype(jnp.float32), h0)
    np.testing.assert_allclose(np.asarray(hs_k), np.asarray(hs_a), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hl_k), np.asarray(hl_a), atol=1e-4)
