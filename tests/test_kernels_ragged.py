"""Valid-length GEMM + ragged flash attention: pad-content invariance.

Hypothesis property tests: the pad-shedding kernels must be *exactly*
invariant to the contents of pad regions — randomized garbage in pad
rows/columns/heads cannot leak into valid outputs, which must stay allclose
to the ``kernels/ref.py`` oracles over the compacted (valid-only) operands.
That is the correctness contract that lets the executor skip masking
entirely on the pallas backend.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.execplan import SeqLayout  # noqa: E402
from repro.kernels import ref  # noqa: E402
from repro.kernels.flash_attention import ragged_flash_attention  # noqa: E402
from repro.kernels.tiled_gemm import (  # noqa: E402
    dense_block_count,
    tiled_gemm_valid,
)


@settings(max_examples=20, deadline=None)
@given(
    data=st.data(),
    m=st.integers(2, 6),
    n=st.integers(2, 6),
    k=st.integers(2, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_valid_gemm_invariant_to_pad_contents(data, m, n, k, seed):
    """Garbage in the pad regions of x and w changes nothing: valid output
    region == dense ref over zero-compacted operands, pad region == 0."""
    bm, bn, bk = 4, 4, 4
    m, n, k = m * bm, n * bn, k * bk
    vm = data.draw(st.integers(1, m), label="valid_m")
    vn = data.draw(st.integers(1, n), label="valid_n")
    vk = data.draw(st.integers(1, k), label="valid_k")
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    # clean operands: zeros in every pad region (what zero-padded weights
    # and scattered activations hold in the real executor)
    xc = x.copy()
    xc[vm:] = 0
    xc[:, vk:] = 0
    wc = w.copy()
    wc[vk:] = 0
    wc[:, vn:] = 0
    expected = np.asarray(ref.tiled_gemm_ref(jnp.asarray(xc), jnp.asarray(wc)))
    # garbage operands: random junk in the same pad regions
    xg = x.copy()
    xg[vm:] = rng.normal(size=(m - vm, k)) * 100
    xg[:, vk:] = rng.normal(size=(m, k - vk)) * 100
    wg = w.copy()
    wg[vk:] = rng.normal(size=(k - vk, n)) * 100
    wg[:, vn:] = rng.normal(size=(k, n - vn)) * 100

    out, cnt = tiled_gemm_valid(
        jnp.asarray(xg), jnp.asarray(wg), valid_m=vm, valid_n=vn, valid_k=vk,
        block_m=bm, block_n=bn, block_k=bk, count_blocks=True, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(out), expected, atol=1e-4)
    assert not np.any(np.asarray(out)[vm:])
    assert not np.any(np.asarray(out)[:, vn:])
    # the kernel's measured live blocks == the analytic ceil(valid/block)
    assert int(cnt) == dense_block_count(
        m, n, k, valid_m=vm, valid_n=vn, valid_k=vk,
        block_m=bm, block_n=bn, block_k=bk,
    )


@settings(max_examples=15, deadline=None)
@given(
    tiles=st.lists(st.integers(0, 6), min_size=2, max_size=4).filter(
        lambda t: max(t) > 0),
    h=st.integers(1, 4),
    vh=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_ragged_flash_invariant_to_pad_contents(tiles, h, vh, seed):
    """Garbage in pad rows (positions == -1) and pad head slots beyond
    valid_heads never reaches valid outputs; valid rows of valid heads
    match flash_attention_ref over the compacted sequence."""
    vh = min(vh, h)
    lay = SeqLayout(tuple(tiles))
    s, hd, b = lay.padded_len, 8, 2
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(b, h, s, hd)).astype(np.float32)
    k = rng.normal(size=(b, h, s, hd)).astype(np.float32)
    v = rng.normal(size=(b, h, s, hd)).astype(np.float32)
    pad = ~lay.valid
    qg, kg, vg = q.copy(), k.copy(), v.copy()
    for a in (qg, kg, vg):
        a[:, :, pad] = rng.normal(size=(b, h, int(pad.sum()), hd)) * 100
        a[:, vh:] = rng.normal(size=(b, h - vh, s, hd)) * 100

    out = ragged_flash_attention(
        jnp.asarray(qg), jnp.asarray(kg), jnp.asarray(vg),
        positions=lay.positions, valid_heads=vh, block_q=4, block_k=4,
        interpret=True,
    )
    out = np.asarray(out)
    assert not np.any(out[:, :, pad]), "pad rows must be exactly zero"
    assert not np.any(out[:, vh:]), "pad head slots must be exactly zero"
    if lay.seq:
        qc = jnp.asarray(q[:, :vh][:, :, lay.rows])
        kc = jnp.asarray(k[:, :vh][:, :, lay.rows])
        vc = jnp.asarray(v[:, :vh][:, :, lay.rows])
        expected = np.asarray(ref.flash_attention_ref(qc, kc, vc, causal=True))
        np.testing.assert_allclose(out[:, :vh][:, :, lay.rows], expected,
                                   atol=1e-5)
