"""Smoke test of the measured-vs-simulated calibration loop.

Injects synthetic "measurements" (the simulator's own output under known
perturbed constants) so no multi-device subprocess is needed: the hillclimb
must drive the residual loss (close to) zero and recover simulated times
near the targets.
"""
import numpy as np

from experiments.calibrate import DEFAULT_CONSTANTS, calibrate, simulated
from experiments.hillclimb import coordinate_hillclimb


def test_coordinate_hillclimb_minimizes_quadratic():
    best, loss = coordinate_hillclimb(
        lambda p: (p["a"] - 4.0) ** 2 + (p["b"] - 0.25) ** 2,
        {"a": 1.0, "b": 1.0},
    )
    assert loss < 0.05
    assert abs(best["a"] - 4.0) < 0.5 and abs(best["b"] - 0.25) < 0.1


def test_calibrate_reduces_residuals():
    # synthesize measurements from a "true" host 2x slower than the default
    # guess with a slower interconnect — the loop must close most of the gap
    true = dict(DEFAULT_CONSTANTS)
    true["host_flops"] = DEFAULT_CONSTANTS["host_flops"] / 2
    true["link_bw"] = DEFAULT_CONSTANTS["link_bw"] / 4
    measured = simulated(true)
    assert all(v > 0 for v in measured.values())

    report = calibrate(measured=measured, rounds=6)
    assert report["loss"] < report["start_loss"]
    assert report["loss"] < 0.05
    ratios = np.array(list(report["residual_ratio"].values()))
    assert np.all(np.abs(np.log(ratios)) < 0.25), report["residual_ratio"]


def test_calibration_overrides_restore():
    """apply_calibration returns previous values and round-trips."""
    from repro.core import costmodel, simulator

    before = simulator.TILE_OVERHEAD
    prev = costmodel.apply_calibration({"TILE_OVERHEAD": 0.5})
    assert simulator.TILE_OVERHEAD == 0.5 and prev == {"TILE_OVERHEAD": before}
    costmodel.apply_calibration(prev)
    assert simulator.TILE_OVERHEAD == before
    try:
        costmodel.apply_calibration({"NOT_A_CONSTANT": 1.0})
    except ValueError:
        pass
    else:
        raise AssertionError("unknown constant must be rejected")
