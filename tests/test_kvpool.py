"""PagedKVPool: allocation invariants (incl. hypothesis property test) and
paged-decode == dense-decode token equality on the default executor."""
import jax
import numpy as np
import pytest

from repro.models import init_params
from repro.serving import PagedKVPool, PoolExhausted, Request, ServingEngine
from repro.serving.kvpool import NULL_PAGE

from helpers import smoke_cfg


# --- deterministic bookkeeping ------------------------------------------------

def test_admit_ensure_retire_roundtrip():
    pool = PagedKVPool(num_pages=9, page_size=4, num_slots=2, pages_per_slot=4)
    assert pool.free_pages == 8  # page 0 reserved as the null page
    pool.admit(0, initial_positions=5, max_positions=13)  # 2 pages now, 4 max
    pool.check()
    assert pool.free_pages == 6 and pool.available == 4
    assert np.all(pool.block_table[0, :2] != NULL_PAGE)
    pool.ensure(0, 7)  # still within page 2
    assert pool.free_pages == 6
    pool.ensure(0, 8)  # crosses into page 3
    pool.check()
    assert pool.free_pages == 5
    pages = pool.retire(0)
    pool.check()
    assert len(pages) == 3 and pool.free_pages == 8 and pool.available == 8
    assert np.all(pool.block_table[0] == NULL_PAGE)


def test_reservation_blocks_oversubscription():
    pool = PagedKVPool(num_pages=5, page_size=4, num_slots=2, pages_per_slot=4)
    pool.admit(0, initial_positions=4, max_positions=12)  # 1 allocated, 3 reserved
    assert pool.available == 1
    assert not pool.can_admit(8)  # needs 2, only 1 admissible
    with pytest.raises(PoolExhausted):
        pool.admit(1, initial_positions=8, max_positions=8)
    pool.admit(1, initial_positions=4, max_positions=4)
    pool.check()
    # slot 0 can always grow into its reservation
    pool.ensure(0, 11)
    pool.check()
    with pytest.raises(PoolExhausted):
        pool.ensure(0, 12)  # beyond its own reservation


def test_retired_pages_are_reused():
    pool = PagedKVPool(num_pages=4, page_size=2, num_slots=1, pages_per_slot=3)
    pool.admit(0, 6, 6)
    first = pool.retire(0)
    pool.admit(0, 6, 6)
    second = pool.retire(0)
    assert sorted(first) == sorted(second)  # same physical pages recycled
    pool.check()


def test_request_larger_than_block_table_rejected():
    pool = PagedKVPool(num_pages=16, page_size=2, num_slots=1, pages_per_slot=2)
    assert not pool.can_admit(5)
    with pytest.raises(ValueError):
        pool.admit(0, 2, 5)


# --- hypothesis: random admit/retire sequences never leak -------------------

def test_random_lifecycle_never_leaks_or_double_allocates():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=60, deadline=None)
    @hypothesis.given(
        ops=st.lists(
            st.tuples(st.sampled_from(["admit", "ensure", "retire"]),
                      st.integers(0, 3), st.integers(0, 40)),
            max_size=60,
        ),
        page_size=st.integers(1, 8),
        num_pages=st.integers(2, 24),
    )
    def run(ops, page_size, num_pages):
        pool = PagedKVPool(num_pages, page_size, num_slots=4, pages_per_slot=6)
        live = {}
        for op, slot, arg in ops:
            if op == "admit" and not pool.active[slot]:
                need = arg + 1
                if pool.can_admit(need):
                    pool.admit(slot, initial_positions=min(need, arg or 1),
                               max_positions=need)
                    live[slot] = need
            elif op == "ensure" and pool.active[slot]:
                pos = min(arg, live[slot] - 1)
                pool.ensure(slot, pos)
            elif op == "retire" and pool.active[slot]:
                pool.retire(slot)
                live.pop(slot)
            pool.check()
        for slot in list(live):
            pool.retire(slot)
        pool.check()
        assert pool.free_pages == num_pages - 1

    run()


# --- paged decode == dense decode, token for token ---------------------------

def _mixed_requests():
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(9):
        n = int(rng.integers(3, 14))
        reqs.append(Request(
            uid=i, prompt=[int(t) for t in rng.integers(1, 400, n)],
            max_new_tokens=int(rng.integers(1, 12)),
        ))
    return reqs


@pytest.mark.parametrize("page_size,num_pages", [(4, None), (8, 9)])
def test_paged_decode_matches_dense_decode(page_size, num_pages):
    """Continuous batching over the paged pool produces greedy tokens
    identical to the dense-cache wave path — including with a deliberately
    tight pool (num_pages=9) that forces admission to wait on capacity."""
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    def run(scheduler):
        eng = ServingEngine(params, cfg, max_batch=3, max_len=32,
                            scheduler=scheduler, page_size=page_size,
                            num_pages=num_pages)
        for r in _mixed_requests():
            eng.submit(r)
        done = eng.run()
        assert all(r.done for r in done) and len(done) == 9
        return {r.uid: r.output for r in done}, eng.stats

    dense, _ = run("wave")
    paged, stats = run("continuous")
    assert paged == dense
    # every token beyond each request's first (sampled off prefill logits)
    # came from a continuous decode step
    assert stats["decode_steps"] > 0
    assert stats["decode_tokens"] == sum(len(v) for v in paged.values()) - 9


def test_pool_too_small_for_one_request_raises():
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(params, cfg, max_batch=2, max_len=32,
                        scheduler="continuous", page_size=4, num_pages=3)
    eng.submit(Request(uid=0, prompt=list(range(1, 17)), max_new_tokens=8))
    with pytest.raises(RuntimeError, match="cannot fit"):
        eng.run()
