"""Serving observability (``repro.obs``): tracer, metrics registry, drift
monitor, and their engine integration.

The acceptance bars from the engine side:

* a traced serve run exports well-formed Chrome trace-event JSON with zero
  open spans, and the per-request phase spans cover >= 95 % of every
  request's submit→retire wall time — asserted on BOTH executors (the
  model-zoo path in-process, the 4-device uneven Galaxy plan in a
  subprocess);
* greedy tokens are bitwise identical with telemetry on or off;
* with telemetry disabled the engine executes ZERO tracer / histogram
  calls per token (structural gate — call counting, not wall clock);
* stats no longer silently persist across ``run()`` calls on a reused
  engine: ``reset_stats()`` zeroes the run scope, lifetime survives.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.obs import (
    DriftMonitor, MetricsRegistry, RequestTracks, Tracer,
    itl_seconds, percentile, percentile_summary, ttft_percentiles,
)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from helpers import smoke_cfg

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --- metrics registry ---------------------------------------------------------

def test_counter_scopes_and_monotonicity():
    reg = MetricsRegistry()
    c = reg.counter("decode_steps")
    c.inc()
    c.inc(4)
    assert c.value == 5 and c.lifetime == 5
    c.set_run(9)  # the stats-facade write path (read + assign)
    assert c.value == 9 and c.lifetime == 9
    with pytest.raises(ValueError, match="may not decrease"):
        c.set_run(3)
    with pytest.raises(ValueError, match="negative"):
        c.inc(-1)
    reg.reset_run()
    assert c.value == 0 and c.lifetime == 9
    c.inc(2)
    assert c.value == 2 and c.lifetime == 11


def test_gauge_and_histogram_scopes():
    reg = MetricsRegistry()
    g = reg.gauge("queue_depth")
    g.set(3)
    g.set_max(1)  # peak tracking keeps the max
    assert g.value == 3
    g.set_max(7)
    assert g.value == 7

    h = reg.histogram("ttft_s")
    for v in (1.0, 2.0, 2.0, 10.0):
        h.observe(v)
    assert h.count == 4
    assert h.percentile(50) == 2.0
    assert h.value_counts() == {1.0: 1, 2.0: 2, 10.0: 1}
    reg.reset_run()
    assert h.count == 0 and g.value == 0
    assert h.percentile(50, scope="lifetime") == 2.0
    s = h.summary(scope="lifetime")
    assert s["n"] == 4 and s["min"] == 1.0 and s["max"] == 10.0


def test_registry_collision_snapshot_prometheus():
    reg = MetricsRegistry()
    reg.counter("requests").inc(3)
    reg.gauge("kv_pool_occupancy").set(0.5)
    reg.histogram("itl_s").observe(0.25)
    with pytest.raises(ValueError, match="different kind"):
        reg.gauge("requests")
    assert "requests" in reg and "nope" not in reg

    snap = reg.snapshot()
    assert snap["scope"] == "run"
    assert snap["counters"]["requests"] == 3
    assert snap["gauges"]["kv_pool_occupancy"] == 0.5
    assert snap["histograms"]["itl_s"]["n"] == 1
    with pytest.raises(ValueError):
        reg.snapshot(scope="bogus")

    text = reg.to_prometheus()
    assert "# TYPE repro_requests_total counter" in text
    assert "repro_requests_total 3" in text
    assert "# TYPE repro_kv_pool_occupancy gauge" in text
    assert "# TYPE repro_itl_s summary" in text
    assert 'repro_itl_s{quantile="0.5"} 0.25' in text
    assert "repro_itl_s_count 1" in text


def test_shared_latency_helpers_and_bench_wrapper():
    class R:
        def __init__(self, submit, times):
            self.submit_time = submit
            self.token_times = times

    reqs = [R(0.0, [1.0, 1.5, 2.5]), R(1.0, [1.2]), R(None, []), R(0.5, [])]
    assert percentile([], 50) != percentile([], 50)  # NaN on empty
    assert percentile([3.0, 1.0, 2.0], 50) == 2.0
    assert percentile_summary([1.0, 2.0])["p50"] == 1.0  # nearest-rank
    assert itl_seconds(reqs) == [0.5, 1.0]
    out = ttft_percentiles(reqs)
    assert set(out) == {"p50", "p95", "n"} and out["n"] == 2
    assert out["p50"] == pytest.approx(0.2) and out["p95"] == 1.0

    # benchmarks/run.py keeps its historic entry point as a thin wrapper
    sys.path.insert(0, REPO)
    try:
        from benchmarks.run import ttft_percentiles as bench_ttft
        assert bench_ttft(reqs) == out
    finally:
        sys.path.remove(REPO)


# --- tracer -------------------------------------------------------------------

def _fake_clock(times):
    it = iter(times)
    return lambda: next(it)


def test_tracer_chrome_json_wellformed():
    # clock: t0, begin a, begin b, end b, instant, end a
    tr = Tracer(clock=_fake_clock([0.0, 1e-6, 2e-6, 5e-6, 6e-6, 9e-6]))
    tr.begin("engine", "outer", step=1)
    tr.begin("engine", "inner")
    tr.end("engine")
    tr.instant("engine", "mark")
    tr.end("engine", tokens=3)

    obj = tr.to_json()
    assert set(obj) == {"traceEvents", "displayTimeUnit"}
    meta = [e for e in obj["traceEvents"] if e["ph"] == "M"]
    assert {m["name"] for m in meta} == {"process_name", "thread_name"}
    spans = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    assert [e["name"] for e in spans] == ["outer", "inner"]  # sorted by ts
    for e in spans:
        assert {"name", "cat", "ph", "pid", "tid", "ts", "dur", "args"} <= set(e)
        assert e["dur"] >= 0
    outer, inner = spans
    # strict nesting: inner lies within outer on the same track
    assert inner["tid"] == outer["tid"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert outer["args"] == {"step": 1, "tokens": 3}
    inst = [e for e in obj["traceEvents"] if e["ph"] == "i"]
    assert len(inst) == 1 and inst[0]["name"] == "mark"


def test_tracer_open_span_export_and_stack_errors():
    tr = Tracer(clock=_fake_clock([0.0, 1e-6, 2e-6, 3e-6]))
    with pytest.raises(RuntimeError, match="no open span"):
        tr.end("engine")
    tr.begin("engine", "loop")
    assert tr.open_spans() == [(tr.tid("engine"), "loop")]
    with pytest.raises(RuntimeError, match="open spans"):
        tr.to_json()
    assert tr.to_json(allow_open=True)["traceEvents"]
    tr.end("engine")
    assert tr.open_spans() == []


def test_tracer_negative_clock_clamped():
    tr = Tracer(clock=_fake_clock([0.0, 5e-6, 3e-6]))  # clock goes backwards
    tr.begin("t", "s")
    tr.end("t")
    [e] = [e for e in tr.to_json()["traceEvents"] if e["ph"] == "X"]
    assert e["dur"] == 0.0


def test_request_tracks_phase_discipline():
    tr = Tracer()
    tk = RequestTracks(tr)
    tk.submit(7)
    with pytest.raises(ValueError, match="already tracked"):
        tk.submit(7)
    tk.phase(7, "prefill", slot=0)
    with pytest.raises(ValueError, match="monotone"):
        tk.phase(7, "prefill")
    tk.event(7, "spec_rollback", rejected=2)
    tk.phase(7, "decode")
    assert tk.is_open(7) and tk.open_uids() == [7]
    tk.finish(7, tokens=4)
    assert not tk.is_open(7) and tk.open_uids() == []
    with pytest.raises(ValueError, match="not in an open phase"):
        tk.finish(7)
    names = [e["name"] for e in tr.to_json()["traceEvents"]
             if e["ph"] == "X"]
    assert names == ["queued", "prefill", "decode"]


def test_request_tracks_random_interleavings_property():
    """Random admit/retire/phase/spec-event interleavings over many
    requests never leave an open or out-of-order span."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.lists(st.tuples(st.integers(0, 7), st.integers(0, 99)),
                        max_size=200))
    @hyp.settings(deadline=None, max_examples=50)
    def run(ops):
        tr = Tracer()
        tk = RequestTracks(tr)
        state = {}  # uid -> phase index (None = retired)
        for uid, r in ops:
            ph = state.get(uid, -1)
            if ph == -1:
                tk.submit(uid)
                state[uid] = 0
            elif ph is None:
                continue  # retired uids never come back
            elif r % 4 == 0 or ph == 2:
                tk.finish(uid, tokens=r)  # retire from any phase
                state[uid] = None
            elif r % 4 == 1:
                tk.event(uid, "spec_rollback", rejected=r)
            else:
                nxt = min(2, ph + (2 if r % 8 == 7 else 1))  # may skip
                tk.phase(uid, RequestTracks.PHASES[nxt])
                state[uid] = nxt
        for uid in list(tk.open_uids()):
            tk.finish(uid)
        assert tr.open_spans() == []
        obj = tr.to_json()  # raises on any un-closed span
        by_tid = {}
        for e in obj["traceEvents"]:
            if e["ph"] == "X":
                by_tid.setdefault(e["tid"], []).append(e)
        for evs in by_tid.values():
            evs.sort(key=lambda e: e["ts"])
            for a, b in zip(evs, evs[1:]):
                assert a["dur"] >= 0
                # phases tile: each span ends where the next begins (or
                # earlier) — never out of order
                assert a["ts"] + a["dur"] <= b["ts"] + 1e-9

    run()


# --- drift monitor ------------------------------------------------------------

def test_drift_monitor_ratios_and_summary():
    reg = MetricsRegistry()
    mon = DriftMonitor(lambda kind, rows, context: 0.5 if kind != "nope"
                       else None, registry=reg)
    assert mon.observe("decode", 1.0, rows=1, context=8) == 2.0
    assert mon.observe("prefill_chunk", 0.25, rows=4, context=8,
                       synced=False) == 0.5
    assert mon.observe("nope", 1.0) is None  # unpriceable: skipped
    assert mon.observe("decode", -1.0) is None  # clock glitch: skipped
    assert len(mon.records) == 2

    s = mon.summary()
    assert s["decode"]["n"] == 1 and s["decode"]["p50"] == 2.0
    assert s["prefill_chunk_dispatch"]["p50"] == 0.5
    assert s["all"]["n"] == 1 and s["all_dispatch"]["n"] == 1
    snap = reg.snapshot()
    assert snap["histograms"]["sim_drift_ratio"]["n"] == 1
    assert snap["histograms"]["sim_drift_ratio_prefill_chunk_dispatch"]["n"] == 1


def test_make_step_pricer_matches_simulator():
    from repro.core import costmodel
    from repro.core.execplan import ExecPlan
    from repro.core.simulator import make_step_pricer, simulate_execplan

    cfg = smoke_cfg("qwen1.5-0.5b")
    ep = ExecPlan.even(2, num_heads=cfg.num_heads, d_ff=cfg.d_ff,
                       head_dim=cfg.head_dim, d_model=cfg.d_model)
    devices = [costmodel.jetson_nano("nano-l", 4.0) for _ in range(2)]
    link = costmodel.mbps(1000)
    price = make_step_pricer(ep, cfg, devices, link)

    t = price("decode", rows=1, context=32)
    assert t == simulate_execplan(ep, cfg, devices, link, 32,
                                  cached_prefix=31).latency
    assert price("spec_verify", rows=5, context=32) == simulate_execplan(
        ep, cfg, devices, link, 32, cached_prefix=27).latency
    assert price("decode", rows=1, context=32) == t  # memoized
    assert price("decode", rows=0, context=32) is None
    assert price("decode", rows=4, context=2) is None
    assert price("draft", rows=3, context=8) is None  # no draft_cfg bound

    with pytest.raises(ValueError, match="devices"):
        make_step_pricer(ep, cfg, devices[:1], link)


# --- engine integration (model-zoo executor, in-process) ----------------------

@pytest.fixture(scope="module")
def zoo():
    from repro.models import init_params
    from repro.serving import TransformerExecutor

    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return TransformerExecutor(params, cfg)  # shared jit caches


def _requests():
    from repro.serving import Request
    return [Request(uid=i, prompt=[1 + (i * 7 + j) % 200 for j in range(6)],
                    max_new_tokens=8 if i % 2 == 0 else 3)
            for i in range(5)]


def _engine(zoo, **kw):
    from repro.serving import ServingEngine
    kw.setdefault("scheduler", "continuous")
    return ServingEngine(executor=zoo, max_batch=2, max_len=32, page_size=8,
                         **kw)


def _span_coverage(tracer, done):
    obj = tracer.to_json()
    spans = [e for e in obj["traceEvents"] if e.get("ph") == "X"]
    names = {e["tid"]: e["args"]["name"] for e in obj["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "thread_name"}
    cov = {}
    for r in done:
        tid = next(t for t, n in names.items() if n == f"req {r.uid}")
        track = [e for e in spans if e["tid"] == tid]
        lo = min(e["ts"] for e in track)
        hi = max(e["ts"] + e["dur"] for e in track)
        cov[r.uid] = (sum(e["dur"] for e in track) / (hi - lo)
                      if hi > lo else 1.0)
    return obj, spans, cov


def test_traced_serve_zoo_acceptance(zoo):
    """The tentpole acceptance on the zoo executor: faithful trace,
    populated snapshot, tokens bitwise-unchanged by telemetry."""
    tracer = Tracer()
    eng = _engine(zoo, tracer=tracer, record_times=True, prefix_cache=True,
                  prefill_chunk=4)
    for r in _requests():
        eng.submit(r)
    done = eng.run()
    assert len(done) == 5

    assert tracer.open_spans() == []
    obj, spans, cov = _span_coverage(tracer, done)
    assert min(cov.values()) >= 0.95  # phases tile submit->retire
    for e in spans:
        assert e["dur"] >= 0 and e["ts"] >= 0
    kinds = {e["name"] for e in spans}
    assert {"queued", "decode"} <= kinds
    assert "prefill_chunk" in kinds or "wave_prefill" in kinds

    snap = eng.metrics.snapshot()
    assert snap["histograms"]["ttft_s"]["n"] == 5
    assert snap["histograms"]["itl_s"]["n"] == sum(
        len(r.output) - 1 for r in done)
    assert snap["gauges"]["kv_pages_peak"] > 0
    assert snap["gauges"]["kv_pages_used"] == 0  # everything retired
    assert 0 <= snap["gauges"]["prefix_hit_rate"] <= 1
    assert snap["counters"]["decode_tokens"] > 0
    assert "spec_accepted_per_round" in snap["histograms"]

    # telemetry off: identical greedy tokens
    eng2 = _engine(zoo, prefix_cache=True, prefill_chunk=4)
    for r in _requests():
        eng2.submit(r)
    done2 = eng2.run()
    assert ({r.uid: tuple(r.output) for r in done}
            == {r.uid: tuple(r.output) for r in done2})


def test_traced_serve_wave_scheduler(zoo):
    from repro.serving import Request
    tracer = Tracer()
    eng = _engine(zoo, tracer=tracer, record_times=True, scheduler="wave")
    for r in _requests():
        eng.submit(r)
    # a zero-budget request must retire with a closed (rejected) span
    eng.submit(Request(uid=99, prompt=list(range(1, 33)), max_new_tokens=4))
    done = eng.run()
    assert tracer.open_spans() == []
    _, spans, cov = _span_coverage(tracer, [r for r in done if r.output])
    assert min(cov.values()) >= 0.95
    assert "wave_prefill" in {e["name"] for e in spans}
    rejected = [e for e in spans if e["args"].get("rejected")]
    assert len(rejected) == 1 and rejected[0]["name"] == "queued"


def test_disabled_telemetry_is_structurally_free(zoo, monkeypatch):
    """Tier-1 overhead gate: with no tracer and no record_times, serving a
    full mix executes ZERO tracer calls and ZERO histogram observations —
    counted at the class level, not timed."""
    calls = []

    def counting(cls, name):
        orig = getattr(cls, name)

        def wrapped(self, *a, **k):
            calls.append((cls.__name__, name))
            return orig(self, *a, **k)
        monkeypatch.setattr(cls, name, wrapped)

    for m in ("begin", "end", "instant", "tid"):
        counting(obs_trace.Tracer, m)
    counting(obs_metrics.Histogram, "observe")

    eng = _engine(zoo)
    for r in _requests():
        eng.submit(r)
    done = eng.run()
    assert sum(len(r.output) for r in done) > 0
    assert calls == []
    assert eng._trace is None and eng._tracks is None

    # a *disabled* tracer is treated exactly like no tracer
    eng2 = _engine(zoo, tracer=Tracer(enabled=False))
    for r in _requests():
        eng2.submit(r)
    eng2.run()
    assert calls == []


def test_stats_facade_and_reset_regression(zoo):
    """Regression for the stats-accumulation bug: a reused engine's stats
    silently summed across run() calls; reset_stats() scopes them per run
    while the registry keeps lifetime totals."""
    eng = _engine(zoo)
    for r in _requests():
        eng.submit(r)
    done1 = eng.run()
    toks1 = sum(len(r.output) for r in done1)
    assert eng.stats["requests"] == 5
    # each request's first token comes from the prefill logits
    assert eng.stats["decode_tokens"] == toks1 - 5

    # without reset: the historic (buggy-looking) accumulation, now at
    # least explicit in the lifetime scope
    eng.reset_stats()
    assert eng.stats["requests"] == 0
    assert eng.stats["decode_tokens"] == 0
    assert eng.metrics.snapshot("lifetime")["counters"]["requests"] == 5

    for r in _requests():
        eng.submit(r)
    done2 = eng.run()
    assert eng.stats["requests"] == 5  # this run only
    assert eng.stats["decode_tokens"] == sum(len(r.output) for r in done2) - 5
    assert eng.metrics.snapshot("lifetime")["counters"]["requests"] == 10

    # facade contract: mapping behavior + derived keys are read-only
    assert set(dict(eng.stats)) == set(eng.stats.keys())
    assert eng.stats == dict(eng.stats)
    with pytest.raises(TypeError, match="derived"):
        eng.stats["spec_acceptance"] = 1.0
    with pytest.raises(TypeError):
        del eng.stats["requests"]
    with pytest.raises(KeyError):
        eng.stats["bogus"]


def test_drift_monitor_engine_integration(zoo):
    """A constant-price pricer sees every decode step and prefill chunk,
    and drift histograms land in the engine's own registry."""
    priced = []

    def pricer(kind, *, rows, context):
        priced.append((kind, rows, context))
        return 1e-3

    eng = _engine(zoo, drift=DriftMonitor(pricer), prefill_chunk=4)
    for r in _requests():
        eng.submit(r)
    done = eng.run()
    kinds = {k for k, _, _ in priced}
    assert kinds == {"decode", "prefill_chunk"}
    assert len(eng.drift.records) == len(priced)
    assert all(rec["ratio"] > 0 for rec in eng.drift.records)
    snap = eng.metrics.snapshot()
    assert snap["histograms"]["sim_drift_ratio"]["n"] == len(priced)
    assert snap["histograms"]["sim_drift_ratio_decode"]["n"] > 0

    # drift never perturbs tokens either
    eng2 = _engine(zoo, prefill_chunk=4)
    for r in _requests():
        eng2.submit(r)
    done2 = eng2.run()
    assert ({r.uid: tuple(r.output) for r in done}
            == {r.uid: tuple(r.output) for r in done2})


# --- galaxy executor (4-device uneven plan, subprocess) -----------------------

def test_traced_serve_galaxy_acceptance():
    """The same acceptance bar through the Galaxy HMP executor: an uneven
    3:2:2:1 plan on 4 forced CPU devices, traced end to end — >= 95 % span
    coverage, ring wire gauges from the plan's RingSchedule, tokens
    bitwise-unchanged by telemetry."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
    import jax
    from repro.core import hmp
    from repro.core.execplan import ExecPlan
    from repro.launch.mesh import make_mesh_compat
    from repro.obs import Tracer
    from repro.serving import GalaxyHMPExecutor, Request, ServingEngine

    ep = ExecPlan(heads=(6, 4, 4, 2), columns=(24, 16, 16, 8),
                  head_dim=2, d_model=32, seq_shares=(3.0, 2.0, 2.0, 1.0))
    mesh = make_mesh_compat((4,), ('model',))
    layers = hmp.init_stack_params(jax.random.PRNGKey(0), 2, 32, 16, 64)
    emb = jax.random.normal(jax.random.PRNGKey(7), (300, 32)) * 0.5
    executor = GalaxyHMPExecutor(layers, emb, ep, mesh)

    def requests():
        return [Request(uid=i,
                        prompt=[1 + (i * 5 + j) % 250 for j in range(6 + i)],
                        max_new_tokens=6 if i % 2 == 0 else 3)
                for i in range(4)]

    def run(tracer):
        eng = ServingEngine(executor=executor, max_batch=2, max_len=40,
                            scheduler='continuous', page_size=8,
                            tracer=tracer, record_times=tracer is not None)
        for r in requests():
            eng.submit(r)
        return eng, eng.run()

    tracer = Tracer()
    eng, done = run(tracer)
    assert tracer.open_spans() == []
    obj = tracer.to_json()
    spans = [e for e in obj['traceEvents'] if e.get('ph') == 'X']
    names = {e['tid']: e['args']['name'] for e in obj['traceEvents']
             if e.get('ph') == 'M' and e['name'] == 'thread_name'}
    for r in done:
        tid = next(t for t, n in names.items() if n == f'req {r.uid}')
        track = [e for e in spans if e['tid'] == tid]
        lo = min(e['ts'] for e in track)
        hi = max(e['ts'] + e['dur'] for e in track)
        assert hi == lo or sum(e['dur'] for e in track) / (hi - lo) >= 0.95

    snap = eng.metrics.snapshot()
    assert snap['histograms']['ttft_s']['n'] == 4
    assert snap['gauges']['kv_pages_peak'] > 0
    # ring transport gauges come from the plan's own RingSchedule
    ws = executor.wire_stats()
    assert snap['gauges']['ring_wire_rows'] == ws['ring_wire_rows'] > 0
    assert 0 < snap['gauges']['ring_wire_fraction'] <= 1

    _, done_off = run(None)
    assert ({r.uid: tuple(r.output) for r in done}
            == {r.uid: tuple(r.output) for r in done_off})
    print('GALAXY-OBS-OK', len(spans))
    """)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    assert "GALAXY-OBS-OK" in proc.stdout
