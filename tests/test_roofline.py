"""Roofline analysis unit tests: HLO parsing, term math, conventions."""
import pytest

from repro.core.costmodel import TPU_V5E
from repro.roofline.analysis import Roofline, _shape_bytes, collective_bytes


def test_shape_bytes():
    assert _shape_bytes("bf16[4,256]") == 4 * 256 * 2
    assert _shape_bytes("(f32[128], f32[128])") == 2 * 128 * 4
    assert _shape_bytes("u32[]") == 0 or _shape_bytes("u32[]") == 4  # scalar
    assert _shape_bytes("pred[16,16]") == 256


def test_collective_parse_async_pairs():
    hlo = """
  %a = bf16[1024]{0} all-gather-start(bf16[64]{0} %x)
  %b = bf16[1024]{0} all-gather-done(bf16[1024]{0} %a)
  %c = f32[512]{0} reduce-scatter(f32[512]{0} %y)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 1024 * 2          # started once
    assert out["reduce-scatter"] == 512 * 4


def test_roofline_terms_and_bottleneck():
    rl = Roofline(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        hlo_flops=197e12,          # exactly 1 second of compute
        hlo_bytes=819e9 * 0.5,     # 0.5s memory
        coll_bytes={"total": 50e9 * 2},  # 2s collective
        model_flops=197e12 * 256 * 0.4,
    )
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(0.5)
    assert rl.t_collective == pytest.approx(2.0)
    assert rl.bottleneck == "collective"
    assert rl.step_time == pytest.approx(2.0)
    assert rl.useful_ratio == pytest.approx(0.4)
    # mfu = model_flops/chips / step_time / peak
    assert rl.mfu == pytest.approx(0.4 / 2.0)


def test_dtype_factor_halves_traffic_terms_only():
    base = dict(arch="x", shape="s", mesh="m", chips=2, hlo_flops=1e12,
                hlo_bytes=819e9, coll_bytes={"total": 50e9},
                model_flops=1e12)
    full = Roofline(**base, dtype_factor=1.0)
    half = Roofline(**base, dtype_factor=0.5)
    assert half.t_memory == pytest.approx(full.t_memory / 2)
    assert half.t_collective == pytest.approx(full.t_collective / 2)
    assert half.t_compute == full.t_compute


def test_hw_constants_match_spec():
    assert TPU_V5E["peak_flops"] == 197e12
    assert TPU_V5E["hbm_bw"] == 819e9
    assert TPU_V5E["ici_bw"] == 50e9
