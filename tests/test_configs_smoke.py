"""Per-architecture smoke tests (required deliverable f): a REDUCED variant
of each assigned family runs one forward AND one train step on CPU with
correct output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, all_configs, get_config
from repro.models import apply_model, init_params
from repro.models.params import padded_vocab
from repro.training import AdamW, cosine_schedule, make_train_step

from helpers import make_batch, make_inputs, smoke_cfg


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = smoke_cfg(arch)
    assert cfg.d_model <= 512 and cfg.num_experts <= 4
    assert cfg.num_layers <= max(2, len(cfg.block_pattern))
    params = init_params(cfg, jax.random.PRNGKey(0))
    kw = make_inputs(cfg)
    logits, cache, aux = apply_model(params, cfg, mode="train", **kw)
    vp = padded_vocab(cfg)
    if cfg.num_codebooks > 1:
        assert logits.shape == (2, 16, cfg.num_codebooks, vp)
    else:
        assert logits.shape == (2, 16, vp)
    assert not bool(jnp.isnan(logits).any())
    assert cache is None


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_no_nan(arch):
    cfg = smoke_cfg(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(cosine_schedule(1e-3, 2, 10))
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    batch = make_batch(cfg)
    params, state, metrics = step(params, state, batch, jax.random.PRNGKey(1))
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    for leaf in jax.tree.leaves(params):
        assert not bool(jnp.isnan(leaf).any())


def test_exact_assigned_configs():
    """The full configs match the assignment sheet exactly."""
    expect = {
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    }
    for name, cfg in all_configs().items():
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == expect[name], (name, got)


def test_moe_expert_counts():
    g = get_config("granite-moe-3b-a800m")
    assert (g.num_experts, g.experts_per_token) == (40, 8)
    o = get_config("olmoe-1b-7b")
    assert (o.num_experts, o.experts_per_token) == (64, 8)


def test_qkv_bias_flags():
    assert get_config("qwen1.5-110b").qkv_bias
    assert get_config("qwen1.5-0.5b").qkv_bias
    assert get_config("codeqwen1.5-7b").qkv_bias
    assert not get_config("stablelm-12b").qkv_bias


def test_param_counts_near_advertised():
    approx = {
        "granite-moe-3b-a800m": 3.3e9,
        "codeqwen1.5-7b": 8.2e9,
        "recurrentgemma-9b": 8.5e9,
        "qwen1.5-110b": 111e9,
        "qwen1.5-0.5b": 0.46e9,
        "stablelm-12b": 12.1e9,
        "llama-3.2-vision-90b": 88e9,
        "xlstm-350m": 0.54e9,
    }
    for name, target in approx.items():
        n = get_config(name).param_count()
        assert abs(n - target) / target < 0.15, (name, n)
