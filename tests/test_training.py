"""Training substrate: loss/grad correctness, optimizer behaviour,
checkpoint roundtrip, end-to-end convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, LMDataPipeline
from repro.models import init_params
from repro.training import (
    AdamW,
    cosine_schedule,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.training.train_loop import cross_entropy

from helpers import smoke_cfg


def test_custom_vjp_ce_matches_naive():
    cfg = smoke_cfg("qwen1.5-0.5b")
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 64))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64).at[0, 0].set(-1)

    def naive(lg):
        lse = jax.nn.logsumexp(lg, -1)
        c = jnp.take_along_axis(lg, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
        m = (labels >= 0).astype(jnp.float32)
        return jnp.sum((lse - c) * m) / jnp.sum(m)

    l1, g1 = jax.value_and_grad(lambda lg: cross_entropy(lg, labels, cfg))(logits)
    l2, g2 = jax.value_and_grad(naive)(logits)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


def test_ce_codebooks():
    cfg = smoke_cfg("musicgen-medium")
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 4, 32))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 4, 4), 0, 32)
    loss = cross_entropy(logits, labels, cfg)
    assert jnp.isfinite(loss) and loss > 0


def test_cosine_schedule():
    sched = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert abs(float(sched(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(sched(jnp.int32(100))) < 2e-4  # decayed near floor
    assert float(sched(jnp.int32(5))) == pytest.approx(5e-4)


def test_adamw_decreases_quadratic():
    opt = AdamW(lambda s: 0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_grad_clipping_applied():
    opt = AdamW(lambda s: 0.0, grad_clip=1.0)  # lr 0: just inspect metrics
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, m = opt.update({"w": jnp.full(3, 100.0)}, state, params)
    assert float(m["grad_norm"]) > 100


def test_training_converges_and_checkpoints(tmp_path):
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(cosine_schedule(1e-3, 5, 60))
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    pipe = iter(LMDataPipeline(cfg, DataConfig(batch_size=4, seq_len=32)))
    losses = []
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, state, m = step(params, state, batch, jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses

    ckpt = str(tmp_path / "ck")
    save_checkpoint(ckpt, 20, params, state, {"arch": cfg.name})
    manifest, p2, s2 = restore_checkpoint(ckpt, params, state)
    assert manifest["step"] == 20 and manifest["arch"] == cfg.name
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(s2.step) == int(state.step)


def test_moe_aux_loss_in_training():
    cfg = smoke_cfg("olmoe-1b-7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(cosine_schedule(1e-3, 2, 10))
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    pipe = iter(LMDataPipeline(cfg, DataConfig(batch_size=2, seq_len=16)))
    batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
    _, _, m = step(params, state, batch, jax.random.PRNGKey(0))
    assert float(m["moe_lb_loss"]) > 0.5  # ~num_experts-normalized, near 1+
    assert float(m["loss"]) > float(m["ce_loss"])  # aux added
