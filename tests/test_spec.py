"""Speculative decoding (``serving/spec.py``): acceptance arithmetic,
draft placement, rollback via block-table truncation, the engine-level
token-pinning contract on both executors, and the verify-chunk pricing.

The multi-device test runs the 4-device uneven 3:2:2:1 Galaxy plan in a
subprocess (pattern per test_execplan.py) with the pool invariants checked
after every speculative round.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.costmodel import DeviceSpec, spec_expected_tokens
from repro.serving import (
    PagedKVPool, Request, ServingEngine, TransformerExecutor,
    longest_accepted_prefix, place_draft,
)

from helpers import smoke_cfg
from test_execplan import run_multidevice


def init_params_for(cfg, seed):
    from repro.models import init_params
    return init_params(cfg, jax.random.PRNGKey(seed))


# --- pure arithmetic ---------------------------------------------------------

def test_longest_accepted_prefix():
    assert longest_accepted_prefix([], []) == 0
    assert longest_accepted_prefix([5, 6, 7], [5, 6, 7]) == 3
    assert longest_accepted_prefix([5, 6, 7], [5, 9, 7]) == 1
    assert longest_accepted_prefix([5, 6], [8, 6]) == 0
    # verified may be longer (the verify chunk carries the bonus row)
    assert longest_accepted_prefix([5, 6], [5, 6, 7]) == 2
    assert longest_accepted_prefix([np.int32(5)], jnp.asarray([5, 2])) == 1


def test_place_draft_picks_fastest():
    devs = [DeviceSpec("a", 2e9, 1e9, 1e9), DeviceSpec("b", 7e9, 1e9, 1e9),
            DeviceSpec("c", 3e9, 1e9, 1e9)]
    assert place_draft(devs) == 1
    assert place_draft(devs[:1]) == 0
    with pytest.raises(ValueError):
        place_draft([])


def test_spec_expected_tokens():
    assert spec_expected_tokens(0.0, 4) == 1.0
    assert spec_expected_tokens(1.0, 4) == 5.0
    # geometric partial sum: 1 + a + ... + a^k
    a, k = 0.7, 3
    assert spec_expected_tokens(a, k) == pytest.approx(
        sum(a ** j for j in range(k + 1)))
    # monotone in both arguments
    assert spec_expected_tokens(0.9, 4) > spec_expected_tokens(0.5, 4)
    assert spec_expected_tokens(0.5, 6) > spec_expected_tokens(0.5, 2)
    with pytest.raises(ValueError):
        spec_expected_tokens(1.5, 4)
    with pytest.raises(ValueError):
        spec_expected_tokens(0.5, 0)


# --- rollback: PagedKVPool.truncate ------------------------------------------

def test_kvpool_truncate_releases_tail_pages():
    pool = PagedKVPool(num_pages=9, page_size=4, num_slots=2, pages_per_slot=4)
    pool.admit(0, initial_positions=6, max_positions=16)  # 2 pages up front
    for p in range(6, 12):
        pool.ensure(0, p)                                 # grows to 3 pages
    assert len(pool.block_table[0].nonzero()[0]) == 3
    free_before = pool.free_pages
    dropped = pool.truncate(0, 7)                         # back to 2 pages
    assert len(dropped) == 1
    assert pool.free_pages == free_before + 1
    assert list(pool.block_table[0, 2:]) == [0, 0, 0, 0] or \
        bool(np.all(pool.block_table[0, 2:] == 0))
    pool.check()
    # no-op when the slot already holds <= pages_for(positions)
    assert pool.truncate(0, 8) == []
    pool.check()
    # the reservation is untouched: the slot can grow back
    pool.ensure(0, 15)
    pool.check()
    with pytest.raises(ValueError):
        pool.truncate(1, 0)  # idle slot


def test_kvpool_truncate_respects_shared_refcounts():
    pool = PagedKVPool(num_pages=9, page_size=4, num_slots=2, pages_per_slot=4)
    pool.admit(0, initial_positions=8, max_positions=8)
    shared = list(pool._allocated[0])
    pool.pin(shared[1])  # a prefix-tree reference to the slot's 2nd page
    free_before = pool.free_pages
    dropped = pool.truncate(0, 4)
    assert dropped == [shared[1]]
    # still pinned: reference dropped but the page must NOT hit the free list
    assert pool.free_pages == free_before
    assert pool.refcount[shared[1]] == 1
    pool.check()
    assert pool.unpin(shared[1])  # last reference -> freed now
    pool.check()


# --- engine contract: spec tokens == plain tokens (zoo executor) -------------

def _requests():
    return [
        Request(uid=i, prompt=[1 + (i * 7 + j) % 200 for j in range(6 + 2 * i)],
                max_new_tokens=9 if i % 2 == 0 else 3)
        for i in range(5)
    ]


def test_spec_matches_plain_on_transformer_executor():
    """Greedy tokens bitwise identical spec on/off, with an *independent*
    draft model (hostile case: frequent rejections exercise rollback)."""
    cfg = smoke_cfg("qwen1.5-0.5b")
    target = TransformerExecutor(init_params_for(cfg, 0), cfg)
    draft = TransformerExecutor(init_params_for(cfg, 9), cfg)  # unrelated

    def run(spec_on):
        eng = ServingEngine(
            executor=target, max_batch=3, max_len=32,
            scheduler="continuous", page_size=4,
            draft_executor=draft if spec_on else None,
            spec_k=4 if spec_on else None)
        for r in _requests():
            eng.submit(r)
        return {r.uid: tuple(r.output) for r in eng.run()}, eng.stats

    plain, _ = run(False)
    spec, stats = run(True)
    assert plain == spec
    assert stats["spec_steps"] > 0
    assert 0 <= stats["spec_accepted"] <= stats["spec_proposed"]
    # the budget cap keeps every round's proposals within the remaining
    # output budget minus the verifier's own token
    assert sum(stats["spec_accept_counts"].values()) == stats["spec_steps"]
    assert stats["spec_acceptance"] == pytest.approx(
        stats["spec_accepted"] / max(stats["spec_proposed"], 1))


def test_spec_identical_draft_accepts_everything():
    """Draft == target: every proposal is accepted (acceptance 100%), and
    rounds emit k+1 tokens until the budget cap bites."""
    cfg = smoke_cfg("qwen1.5-0.5b")
    target = TransformerExecutor(init_params_for(cfg, 0), cfg)
    draft = TransformerExecutor(init_params_for(cfg, 0), cfg)

    eng = ServingEngine(executor=target, max_batch=1, max_len=32,
                        scheduler="continuous", page_size=4,
                        draft_executor=draft, spec_k=3)
    eng.submit(Request(uid=0, prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=8))
    done = eng.run()
    assert len(done[0].output) == 8
    assert eng.stats["spec_accepted"] == eng.stats["spec_proposed"] > 0
    assert eng.stats["spec_acceptance"] == 1.0

    ref = ServingEngine(executor=target, max_batch=1, max_len=32,
                        scheduler="continuous", page_size=4)
    ref.submit(Request(uid=0, prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=8))
    assert ref.run()[0].output == done[0].output


def test_spec_engine_validation():
    cfg = smoke_cfg("qwen1.5-0.5b")
    from repro.serving import SamplerConfig
    params = init_params_for(cfg, 0)
    ex = TransformerExecutor(params, cfg)
    with pytest.raises(ValueError, match="both draft_executor and spec_k"):
        ServingEngine(executor=ex, max_batch=1, max_len=16, spec_k=4)
    with pytest.raises(ValueError, match="both draft_executor and spec_k"):
        ServingEngine(executor=ex, max_batch=1, max_len=16, draft_executor=ex)
    with pytest.raises(ValueError, match="continuous"):
        ServingEngine(executor=ex, max_batch=1, max_len=16, scheduler="wave",
                      draft_executor=ex, spec_k=4)
    with pytest.raises(ValueError, match="greedy-only"):
        ServingEngine(executor=ex, max_batch=1, max_len=16,
                      sampler=SamplerConfig(temperature=0.8),
                      draft_executor=ex, spec_k=4)
    with pytest.raises(ValueError, match="spec_k must be >= 1"):
        ServingEngine(executor=ex, max_batch=1, max_len=16,
                      draft_executor=ex, spec_k=0)


# --- pricing (core/simulator) ------------------------------------------------

def test_spec_decode_summary_and_choose_k():
    import dataclasses

    from repro.configs import get_config
    from repro.core import planner
    from repro.core.execplan import ExecPlan
    from repro.core.profiler import AnalyticProfiler
    from repro.core.simulator import choose_spec_k, spec_decode_summary
    from repro.core import costmodel

    cfg = dataclasses.replace(get_config("distilbert"), num_layers=1)
    devices = costmodel.edge_env("C")
    link = costmodel.mbps(1000)
    prof = AnalyticProfiler(cfg, 128)
    pl = planner.plan(prof.model_profile(), prof.device_profiles(devices))
    ep = ExecPlan.from_plan(pl, head_dim=cfg.head_dim, d_model=cfg.d_model)
    # a draft 1/10th the width should make speculation profitable
    draft_cfg = dataclasses.replace(cfg, d_ff=cfg.d_ff // 4)

    s = spec_decode_summary(ep, cfg, devices, link, draft_cfg=draft_cfg,
                            k=4, acceptance=0.8, context_len=128)
    assert s["expected_tokens"] == pytest.approx(spec_expected_tokens(0.8, 4))
    assert s["t_verify"] > s["t_decode"] > 0  # 5 rows cost more than 1
    assert s["t_draft"] < s["t_decode"]
    assert s["speedup"] == pytest.approx(
        s["time_per_token_plain"] / s["time_per_token_spec"])
    # perfect drafts only help; zero acceptance can only hurt
    hi = spec_decode_summary(ep, cfg, devices, link, draft_cfg=draft_cfg,
                             k=4, acceptance=1.0, context_len=128)
    lo = spec_decode_summary(ep, cfg, devices, link, draft_cfg=draft_cfg,
                             k=4, acceptance=0.0, context_len=128)
    assert hi["speedup"] > 1.0 > lo["speedup"]

    best = choose_spec_k(ep, cfg, devices, link, draft_cfg=draft_cfg,
                         acceptance=0.8, context_len=128, k_max=8)
    assert 1 <= best["k"] <= 8
    for k in (1, 2, 4, 8):
        s_k = spec_decode_summary(ep, cfg, devices, link, draft_cfg=draft_cfg,
                                  k=k, acceptance=0.8, context_len=128)
        assert best["speedup"] >= s_k["speedup"]

    with pytest.raises(ValueError, match="context_len"):
        spec_decode_summary(ep, cfg, devices, link, draft_cfg=draft_cfg,
                            k=4, acceptance=0.8, context_len=5)


# --- 4-device uneven Galaxy plan: rollback + invariants ----------------------

def test_spec_galaxy_uneven_4dev_with_rollback():
    """The acceptance bar: a 4-device uneven 3:2:2:1 Galaxy plan verifying
    a single-device draft's proposals, with >= 1 rejection exercising the
    rollback path and ``PagedKVPool.check()`` passing on both pools after
    every speculative round.  Greedy tokens must be bitwise identical to
    the non-speculative run."""
    run_multidevice("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import hmp
    from repro.core.execplan import ExecPlan
    from repro.launch.mesh import make_mesh_compat
    from repro.configs import get_config, reduced
    from repro.models import init_params
    import repro.serving.engine as eng_mod
    from repro.serving import (GalaxyHMPExecutor, Request, ServingEngine,
                               TransformerExecutor)

    ep = ExecPlan(heads=(6, 4, 4, 2), columns=(24, 16, 16, 8),
                  head_dim=2, d_model=32, seq_shares=(3.0, 2.0, 2.0, 1.0))
    mesh = make_mesh_compat((4,), ('model',))
    layers = hmp.init_stack_params(jax.random.PRNGKey(0), 2, 32, 16, 64)
    emb = jax.random.normal(jax.random.PRNGKey(7), (300, 32)) * 0.5
    target = GalaxyHMPExecutor(layers, emb, ep, mesh)

    dcfg = reduced(get_config('qwen1.5-0.5b'))  # vocab 512 covers the 300
    draft = TransformerExecutor(init_params(dcfg, jax.random.PRNGKey(3)), dcfg)

    # check the refcount algebra on BOTH pools after every spec round
    orig = eng_mod.run_spec_round
    rounds = [0]
    def checked(engine, spec, slots, live, pool, storage):
        out = orig(engine, spec, slots, live, pool, storage)
        pool.check()
        spec.pool.check()
        rounds[0] += 1
        return out
    eng_mod.run_spec_round = checked

    def run(spec_on):
        eng = ServingEngine(executor=target, max_batch=2, max_len=40,
                            scheduler='continuous', page_size=8,
                            draft_executor=draft if spec_on else None,
                            spec_k=4 if spec_on else None)
        for i in range(5):
            eng.submit(Request(
                uid=i, prompt=[1 + (i * 5 + j) % 250 for j in range(6 + 3 * i)],
                max_new_tokens=10 if i % 2 == 0 else 4))
        return {r.uid: tuple(r.output) for r in eng.run()}, eng.stats

    plain, _ = run(False)
    spec_out, stats = run(True)
    assert plain == spec_out, f'tokens diverged: {plain} vs {spec_out}'
    # spec_steps counts per-slot verify chunks; a batched round covers
    # up to max_batch of them
    assert 0 < rounds[0] <= stats['spec_steps'] <= 2 * rounds[0]
    assert stats['spec_proposed'] > stats['spec_accepted'] > 0, (
        'need at least one rejection AND one acceptance, got '
        f"{stats['spec_accepted']}/{stats['spec_proposed']}")
    assert stats['spec_accept_counts'].get(0, 0) >= 1 or any(
        c < 4 for c in stats['spec_accept_counts']), 'rollback never ran'
    print('ok', stats['spec_acceptance'], stats['spec_accept_counts'])
    """, devices=4)
