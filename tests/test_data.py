"""Data pipeline + tokenizer tests."""
import numpy as np

from repro.data import ByteTokenizer, DataConfig, LMDataPipeline

from helpers import smoke_cfg


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    text = "Galaxy: in-situ Transformer inference 🌌"
    ids = tok.encode(text, add_bos=True, add_eos=True)
    assert ids[0] == tok.bos_id and ids[-1] == tok.eos_id
    assert tok.decode(ids) == text


def test_pipeline_shapes_token_mode():
    cfg = smoke_cfg("qwen1.5-0.5b")
    it = iter(LMDataPipeline(cfg, DataConfig(batch_size=4, seq_len=32)))
    b = next(it)
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_embed_mode_with_codebooks():
    cfg = smoke_cfg("musicgen-medium")
    it = iter(LMDataPipeline(cfg, DataConfig(batch_size=2, seq_len=16)))
    b = next(it)
    assert b["embeds"].shape == (2, 16, cfg.d_model)
    assert b["labels"].shape == (2, 16, cfg.num_codebooks)


def test_pipeline_vlm_image_embeds():
    cfg = smoke_cfg("llama-3.2-vision-90b")
    it = iter(LMDataPipeline(cfg, DataConfig(batch_size=2, seq_len=16)))
    b = next(it)
    assert b["img_embeds"].shape == (2, cfg.num_image_tokens, cfg.d_model)


def test_pipeline_deterministic_per_seed():
    cfg = smoke_cfg("qwen1.5-0.5b")
    a = next(iter(LMDataPipeline(cfg, DataConfig(batch_size=2, seq_len=8, seed=3))))
    b = next(iter(LMDataPipeline(cfg, DataConfig(batch_size=2, seq_len=8, seed=3))))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_pipeline_text_backed(tmp_path):
    path = tmp_path / "corpus.txt"
    path.write_bytes(b"hello galaxy " * 500)
    cfg = smoke_cfg("qwen1.5-0.5b")
    it = iter(LMDataPipeline(cfg, DataConfig(batch_size=2, seq_len=16,
                                             text_path=str(path))))
    b = next(it)
    assert b["tokens"].max() < 256  # byte tokens
