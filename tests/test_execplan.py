"""ExecPlan: uneven planner output executed end-to-end.

Pure-python tests cover the pad-and-mask algebra (exactness needs no mesh:
zero-padded params compute the identical layer function even on one
device).  Multi-device tests run in subprocesses with
``--xla_force_host_platform_device_count`` (pattern per
test_hmp_distributed.py): an uneven plan from ``planner.plan`` must match
``reference_layer`` through hmp / hmp_ring, and the ServingEngine must
drive prefill + decode through the Galaxy schedule.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import hmp, planner
from repro.core.execplan import ExecPlan
from repro.core.planner import DeviceProfile, ModelProfile

REPO = os.path.join(os.path.dirname(__file__), "..")


def run_multidevice(body: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def _uneven_plan(caps=(3.0, 2.0, 2.0, 1.0), heads=16, columns=64):
    model = ModelProfile("tiny", num_layers=2, num_heads=heads,
                         mlp_columns=columns, m_att=1e6, m_mlp=2e6)
    devs = [DeviceProfile(f"d{i}", c, 1e12) for i, c in enumerate(caps)]
    return planner.plan(model, devs)


# --- pure-python: geometry + padding algebra ---------------------------------

def test_from_plan_geometry():
    pl = _uneven_plan()
    assert pl.feasible
    ep = ExecPlan.from_plan(pl, head_dim=2, d_model=32)
    assert ep.heads == (6, 4, 4, 2) and ep.columns == (24, 16, 16, 8)
    assert ep.num_heads == 16 and ep.d_ff == 64
    assert ep.pad_heads == 6 and ep.pad_columns == 24
    assert ep.padded_heads == 24 and ep.padded_ff == 96
    assert not ep.is_even
    assert ep.head_mask().sum() == 16 and ep.column_mask().sum() == 64
    assert 0.3 < ep.padding_waste() < 0.45
    # planner output keeps the SP axis equal unless links are given
    assert not ep.uneven_seq and ep.seq_padding_waste() == 0.0
    assert ep.seq_tile(32) == 8
    # non-dividing lengths get a ragged layout instead of an error
    assert ep.seq_tiles(30) == (8, 8, 7, 7)
    assert ep.seq_tile(30) == 8 and ep.padded_seq(30) == 32
    assert ep.seq_grain == 4


def test_even_plan_is_identity_layout():
    ep = ExecPlan.even(4, num_heads=8, d_ff=64, head_dim=4, d_model=32)
    assert ep.is_even and ep.padded_heads == 8 and ep.padded_ff == 64
    assert ep.padding_waste() == 0.0
    with pytest.raises(ValueError):
        ExecPlan.even(3, num_heads=8, d_ff=64, head_dim=4, d_model=32)


def test_infeasible_plan_rejected():
    pl = planner.Plan(np.array([8, 8]), np.array([32, 32]),
                      np.array([0.5, 0.5]), feasible=False, reason="OOM")
    with pytest.raises(ValueError, match="infeasible"):
        ExecPlan.from_plan(pl, head_dim=2, d_model=32)


def test_pad_layer_params_is_exact():
    """Zero-padding heads/columns leaves the layer *function* unchanged:
    the single-device reference over padded params equals the original."""
    import jax
    import jax.numpy as jnp

    ep = ExecPlan.from_plan(_uneven_plan(), head_dim=2, d_model=32)
    p = hmp.init_layer_params(jax.random.PRNGKey(0), 32, 16, 64)
    pp = ep.pad_layer_params(p)
    assert pp["wq"].shape == (32, 24, 2) and pp["w1"].shape == (32, 96)
    assert pp["wo"].shape == (24, 2, 32) and pp["w2"].shape == (96, 32)
    # pad slots are zero, real slots are the original slices
    hm, cm = ep.head_mask(), ep.column_mask()
    assert not np.any(np.asarray(pp["wq"])[:, ~hm, :])
    assert not np.any(np.asarray(pp["w2"])[~cm, :])
    np.testing.assert_array_equal(
        np.asarray(pp["wq"])[:, hm, :], np.asarray(p["wq"]))
    np.testing.assert_array_equal(
        np.asarray(pp["w1"])[:, cm], np.asarray(p["w1"]))

    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32)) * 0.5
    ref = hmp.reference_layer(p, x)
    out = hmp.reference_layer(pp, x)
    assert float(jnp.abs(out - ref).max()) < 1e-6
    # idempotent: already-padded params pass through
    assert ep.ensure_padded(pp) is pp


def test_param_mismatch_rejected():
    import jax

    ep = ExecPlan.from_plan(_uneven_plan(), head_dim=2, d_model=32)
    p = hmp.init_layer_params(jax.random.PRNGKey(0), 32, 8, 64)  # 8 != 16 heads
    with pytest.raises(ValueError, match="heads"):
        ep.pad_layer_params(p)


def test_to_planner_plan_fractions():
    ep = ExecPlan.from_plan(_uneven_plan(), head_dim=2, d_model=32)
    a, b = ep.compute_fractions()
    assert np.isclose(a.sum(), 1.0) and np.isclose(b.sum(), 1.0)
    ap, bp = ep.compute_fractions(padded=True)
    # padded execution: every device runs the straggler's share
    assert np.allclose(ap, 6 / 16) and np.allclose(bp, 24 / 64)
    assert ep.to_planner_plan().mha.sum() == 16
    assert np.all(ep.to_planner_plan(padded=True).mha == 6)


def test_prefill_gemm_flops_prices_suffix_only():
    """A prefix-cache hit shrinks per-shard prefill GEMM FLOPs to the
    uncached suffix rows (GEMM cost is row-linear; the attention-core
    context term is the simulator's job)."""
    ep = ExecPlan.from_plan(_uneven_plan(), head_dim=2, d_model=32)
    full = ep.prefill_gemm_flops(128)
    half = ep.prefill_gemm_flops(128, cached_prefix=64)
    np.testing.assert_allclose(half, full / 2)
    np.testing.assert_array_equal(half, ep.device_gemm_flops(64))
    # padded view scales the same way (every device at max(units))
    np.testing.assert_allclose(
        ep.prefill_gemm_flops(128, cached_prefix=64, padded=True),
        ep.device_gemm_flops(128, padded=True) / 2)
    for bad in (-1, 128, 200):
        with pytest.raises(ValueError, match="cached_prefix"):
            ep.prefill_gemm_flops(128, cached_prefix=bad)


def _ragged_plan():
    """3:2:2:1 cluster with uneven heads, columns AND sequence tiles."""
    return ExecPlan(heads=(6, 4, 4, 2), columns=(24, 16, 16, 8), head_dim=2,
                    d_model=32, seq_shares=(3.0, 2.0, 2.0, 1.0))


def test_seq_layout_geometry():
    ep = _ragged_plan()
    assert ep.uneven_seq
    assert ep.seq_tiles(128) == (48, 32, 32, 16)  # the acceptance split
    lay = ep.seq_layout(13)
    assert lay.tiles == (5, 3, 3, 2) and lay.seq == 13
    assert lay.pad_tile == 5 and lay.padded_len == 20 and not lay.is_dense
    # rows/positions are inverse maps; pad rows carry -1
    assert lay.rows.shape == (13,) and lay.positions.shape == (20,)
    np.testing.assert_array_equal(lay.positions[lay.rows], np.arange(13))
    assert (lay.positions[~lay.valid] == -1).all()
    assert lay.valid.sum() == 13
    np.testing.assert_array_equal(lay.offsets, [0, 5, 8, 11])
    assert 0 < lay.padding_waste() < 1
    # padded plan view ships the straggler's fraction on every device
    padded = ep.to_planner_plan(padded=True)
    assert np.allclose(padded.seq, 3.0 / 8.0)
    assert "seq=" in ep.describe() and "sp_waste" in ep.describe()


def test_seq_layout_scatter_gather_roundtrip():
    import jax

    ep = _ragged_plan()
    lay = ep.seq_layout(13)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 13, 4))
    xp = lay.scatter(x)
    assert xp.shape == (2, 20, 4)
    np.testing.assert_allclose(np.asarray(lay.gather(xp)), np.asarray(x))
    # pad rows are zero after scatter
    assert not np.any(np.asarray(xp)[:, ~lay.valid])
    # dense layouts are identities (keeps the pre-ragged XLA graph)
    dense = ep.seq_layout(16)  # 3:2:2:1 of 16 -> (6,4,4,2), sums to pad? no
    # 16 * [0.375, .25, .25, .125] = (6,4,4,2): pad_tile 6, padded 24 — ragged
    assert not dense.is_dense
    even = ExecPlan.even(4, num_heads=8, d_ff=64, head_dim=4, d_model=32)
    lay_even = even.seq_layout(16)
    assert lay_even.is_dense and lay_even.scatter(x) is x


def test_seq_layout_attention_mask():
    lay = _ragged_plan().seq_layout(7)  # tiles (3,2,1,1), pad 3
    m = lay.attention_mask()
    pos, valid = lay.positions, lay.valid
    for i in range(lay.padded_len):
        for j in range(lay.padded_len):
            if valid[i]:
                assert m[i, j] == (valid[j] and pos[j] <= pos[i])
            else:
                assert m[i, j]  # pad queries attend everywhere (finite softmax)


def test_sequence_partition_bandwidth_aware():
    """planner.sequence_partition: capacity-proportional without links,
    shifted off the slow hop with them, loud on a degenerate byte weight."""
    from repro.core import costmodel
    from repro.core.planner import sequence_partition

    out = planner.sequence_partition(128, [3.0, 2.0, 2.0, 1.0])
    assert out.tolist() == [48, 32, 32, 16]

    caps = [1.0, 1.0, 1.0, 1.0]
    links = [costmodel.mbps(1000), costmodel.mbps(1000),
             costmodel.mbps(100), costmodel.mbps(1000)]
    aware = sequence_partition(128, caps, links)  # default unit_bytes works
    assert aware.sum() == 128 and (aware >= 0).all()
    # the slow hop 2->3 carries every tile except device 3's: the search
    # must shift rows onto device 3 to shrink the slow link's traffic
    assert aware[3] == aware.max() and aware[3] > 32, aware.tolist()
    # a zero byte weight would silently disable the bandwidth term
    with pytest.raises(ValueError, match="unit_bytes"):
        sequence_partition(128, caps, links, unit_bytes=0.0)
    # uniform links + uniform caps: stays the equal split
    assert sequence_partition(
        128, caps, costmodel.mbps(1000)).tolist() == [32, 32, 32, 32]


def test_plan_with_links_carries_uneven_seq():
    from repro.core import costmodel

    links = [costmodel.mbps(1000), costmodel.mbps(1000),
             costmodel.mbps(100), costmodel.mbps(1000)]
    model = ModelProfile("tiny", 2, 16, 64, 1e6, 2e6)
    devs = [DeviceProfile(f"d{i}", 1.0, 1e12) for i in range(4)]
    pl = planner.plan(model, devs, links, seq_units=128)
    assert pl.feasible
    assert np.isclose(pl.seq.sum(), 1.0)
    assert pl.seq.max() > 0.26  # no longer the equal split
    # heads/columns are untouched by the SP solve
    assert pl.mha.sum() == 16 and pl.mlp.sum() == 64
    ep = ExecPlan.from_plan(pl, head_dim=2, d_model=32)
    assert ep.uneven_seq


def test_seq_shares_validation():
    with pytest.raises(ValueError, match="seq_shares"):
        ExecPlan(heads=(4, 4), columns=(8, 8), head_dim=2, d_model=16,
                 seq_shares=(1.0,))
    with pytest.raises(ValueError, match="non-negative"):
        ExecPlan(heads=(4, 4), columns=(8, 8), head_dim=2, d_model=16,
                 seq_shares=(-1.0, 2.0))


def test_compute_backend_knob():
    """Backend validation, the shed-aware padded planner view, and the
    effective-vs-padded FLOPs accounting behind ``describe()``."""
    ep = ExecPlan.from_plan(_uneven_plan(), head_dim=2, d_model=32)
    assert ep.compute_backend == "xla"
    with pytest.raises(ValueError, match="compute_backend"):
        ep.with_backend("cuda")
    pal = ep.with_backend("pallas")
    assert pal.compute_backend == "pallas" and pal.heads == ep.heads

    # xla padded view executes max(units); pallas sheds back to assigned
    assert np.all(ep.to_planner_plan(padded=True).mha == ep.pad_heads)
    shed = pal.to_planner_plan(padded=True)
    assert np.all(shed.mha == np.asarray(ep.heads))
    assert np.all(shed.mlp == np.asarray(ep.columns))
    # ...but the transport side still ships the padded sequence tile
    assert np.allclose(shed.seq, ep.to_planner_plan(padded=True).seq)

    eff = ep.device_gemm_flops()
    pad = ep.device_gemm_flops(padded=True)
    assert np.all(eff <= pad) and len(set(pad)) == 1
    assert 0 < ep.flops_shed() < ep.padding_waste() + 0.1
    # describe prints per-device effective-vs-padded FLOPs + the backend
    assert "eff/pad flops=[" in ep.describe()
    assert "backend=pallas" in pal.describe()


def test_simulator_scores_shed_backend():
    """simulate_execplan(padded=True) on a pallas plan prices effective
    compute: between the unpadded view and the fully padded xla view."""
    import dataclasses

    from repro.configs import get_config
    from repro.core import costmodel
    from repro.core.profiler import AnalyticProfiler
    from repro.core.simulator import simulate_execplan

    cfg = dataclasses.replace(get_config("distilbert"), num_layers=1)
    devices = [
        costmodel.DeviceSpec(f"e{i}", flops=c * 7.1e9, mem_bw=4.0e9,
                             memory_budget=1.5e9)
        for i, c in enumerate([3.0, 2.0, 2.0, 1.0])
    ]
    link = costmodel.mbps(1000)
    prof = AnalyticProfiler(cfg, 128)
    pl = planner.plan(prof.model_profile(), prof.device_profiles(devices))
    ep = ExecPlan.from_plan(pl, head_dim=cfg.head_dim, d_model=cfg.d_model)

    plain = simulate_execplan(ep, cfg, devices, link, 128, overlap=True)
    padded = simulate_execplan(ep, cfg, devices, link, 128, overlap=True,
                               padded=True)
    shed = simulate_execplan(ep.with_backend("pallas"), cfg, devices, link,
                             128, overlap=True, padded=True)
    assert plain.latency - 1e-12 <= shed.latency <= padded.latency + 1e-12
    # the equal seq split makes transport identical: shedding recovers the
    # whole compute-side padding premium here
    assert shed.latency < padded.latency


# --- multi-device: uneven plans through the real executor --------------------

def test_uneven_plan_matches_reference():
    """Acceptance: capacities [3,2,2,1], heads=16, columns=64 planned by
    planner.plan, executed through hmp/hmp_ring/megatron on meshes carved
    from an 8-device host platform — allclose vs reference_layer."""
    run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import hmp, planner
        from repro.core.execplan import ExecPlan
        from repro.core.planner import DeviceProfile, ModelProfile
        from repro.launch.mesh import make_mesh_compat

        def plan_for(caps, heads=16, columns=64):
            model = ModelProfile('tiny', 2, heads, columns, 1e6, 2e6)
            devs = [DeviceProfile(f'd{i}', c, 1e12) for i, c in enumerate(caps)]
            pl = planner.plan(model, devs)
            assert pl.feasible, pl.reason
            return ExecPlan.from_plan(pl, head_dim=2, d_model=32)

        cases = [
            (plan_for([3.0, 2.0, 2.0, 1.0]),
             make_mesh_compat((4,), ('model',), devices=jax.devices()[:4])),
            (plan_for([3.0, 2.0, 2.0, 1.0, 4.0, 1.0, 2.0, 3.0]),
             make_mesh_compat((8,), ('model',))),
        ]
        p = hmp.init_layer_params(jax.random.PRNGKey(0), 32, 16, 64)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
        ref = hmp.reference_layer(p, x)
        for ep, mesh in cases:
            assert not ep.is_even, ep.describe()
            for name in ('hmp', 'hmp_ring', 'megatron'):
                out = hmp.SCHEDULES[name](p, x, mesh, plan=ep)
                err = float(jnp.abs(out - ref).max())
                assert err < 1e-5, (name, ep.describe(), err)
                print(ep.num_devices, name, 'ok', err)
    """)


def test_uneven_stack_prefill_decode_matches_reference():
    """hmp_prefill + hmp_decode under an uneven plan == full-context
    reference recompute, including a non-dividing prompt length."""
    run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import hmp, planner
        from repro.core.execplan import ExecPlan
        from repro.core.planner import DeviceProfile, ModelProfile
        from repro.launch.mesh import make_mesh_compat

        caps = [3.0, 2.0, 2.0, 1.0, 4.0, 1.0, 2.0, 3.0]
        model = ModelProfile('tiny', 2, 16, 64, 1e6, 2e6)
        devs = [DeviceProfile(f'd{i}', c, 1e12) for i, c in enumerate(caps)]
        ep = ExecPlan.from_plan(planner.plan(model, devs), head_dim=2, d_model=32)
        mesh = make_mesh_compat((8,), ('model',))

        layers = hmp.init_stack_params(jax.random.PRNGKey(0), 2, 32, 16, 64)
        s, s_pad, extra = 11, ep.padded_seq(11), 3
        x_full = jax.random.normal(jax.random.PRNGKey(1), (2, s + extra, 32)) * 0.5

        # prefill over the padded prompt
        x_pad = jnp.zeros((2, s_pad, 32)).at[:, :s].set(x_full[:, :s])
        cache = hmp.make_kv_cache(2, 32, 2, mesh, ep)
        y, cache = hmp.hmp_prefill(layers, x_pad, mesh, cache, plan=ep,
                                   overlap=True)
        ref = hmp.reference_stack(layers, x_full)
        err = float(jnp.abs(y[:, :s] - ref[:, :s]).max())
        assert err < 2e-5, ('prefill', err)
        print('prefill ok', err)

        # decode steps s, s+1, ... against the cache
        for t in range(extra):
            y, cache = hmp.hmp_decode(layers, x_full[:, s + t:s + t + 1],
                                      mesh, cache, jnp.int32(s + t), plan=ep)
            err = float(jnp.abs(y[:, 0] - ref[:, s + t]).max())
            assert err < 2e-5, ('decode', t, err)
            print('decode', t, 'ok', err)
    """)


def test_serving_engine_galaxy_executor():
    """Acceptance: ServingEngine drives prefill + decode through the Galaxy
    schedule under an uneven 8-device plan; greedy tokens equal a
    full-context reference recompute."""
    run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import hmp, planner
        from repro.core.execplan import ExecPlan
        from repro.core.planner import DeviceProfile, ModelProfile
        from repro.launch.mesh import make_mesh_compat
        from repro.serving import GalaxyHMPExecutor, Request, ServingEngine

        caps = [3.0, 2.0, 2.0, 1.0, 4.0, 1.0, 2.0, 3.0]
        model = ModelProfile('tiny', 3, 16, 64, 1e6, 2e6)
        devs = [DeviceProfile(f'd{i}', c, 1e12) for i, c in enumerate(caps)]
        ep = ExecPlan.from_plan(planner.plan(model, devs), head_dim=2, d_model=32)
        mesh = make_mesh_compat((8,), ('model',))

        vocab, n_layers = 50, 3
        layers = hmp.init_stack_params(jax.random.PRNGKey(0), n_layers, 32, 16, 64)
        emb = jax.random.normal(jax.random.PRNGKey(7), (vocab, 32)) * 0.5

        exe = GalaxyHMPExecutor(layers, emb, ep, mesh, overlap=True)
        eng = ServingEngine(executor=exe, max_batch=4, max_len=24)
        prompts = [[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11],
                   [4, 7, 1, 9, 2, 8, 3, 6, 5, 10, 12]]
        for i, pr in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=pr, max_new_tokens=4))
        done = {r.uid: r for r in eng.run()}
        assert eng.stats['decode_steps'] >= 3

        # reference: greedy full-context recompute per request
        for uid, pr in enumerate(prompts):
            toks = list(pr)
            for _ in range(4):
                x = emb[jnp.asarray([toks])]
                y = hmp.reference_stack(layers, x)
                logits = y[:, -1] @ emb.T
                toks.append(int(jnp.argmax(logits[0])))
            assert done[uid].output == toks[len(pr):], (
                uid, done[uid].output, toks[len(pr):])
            print('request', uid, 'tokens ok', done[uid].output)

        # direct numeric check of the executor's prefill/decode logits
        toks = jnp.asarray([prompts[0]], jnp.int32)
        cache = exe.make_cache(1, 24)
        logits, cache = exe.prefill(toks, cache)
        x = emb[toks]
        ref_logits = (hmp.reference_stack(layers, x)[:, -1] @ emb.T)
        err = float(jnp.abs(logits - ref_logits).max())
        assert err < 1e-4, ('prefill logits', err)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        logits2, cache = exe.decode(nxt, cache, jnp.int32(toks.shape[1]))
        x2 = jnp.concatenate([toks, nxt], axis=1)
        ref2 = (hmp.reference_stack(layers, emb[x2])[:, -1] @ emb.T)
        err2 = float(jnp.abs(logits2 - ref2).max())
        assert err2 < 1e-4, ('decode logits', err2)
        print('executor logits ok', err, err2)
    """)


def test_serving_engine_galaxy_continuous_batching():
    """Acceptance: continuous batching over the paged head-sharded KV pool
    under an uneven 8-device plan — greedy tokens equal both the wave path
    and a full-context reference recompute, and mixed-length waves (prompts
    sharing a padded bucket) stay exact."""
    run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import hmp, planner
        from repro.core.execplan import ExecPlan
        from repro.core.planner import DeviceProfile, ModelProfile
        from repro.launch.mesh import make_mesh_compat
        from repro.serving import GalaxyHMPExecutor, Request, ServingEngine

        caps = [3.0, 2.0, 2.0, 1.0, 4.0, 1.0, 2.0, 3.0]
        model = ModelProfile('tiny', 3, 16, 64, 1e6, 2e6)
        devs = [DeviceProfile(f'd{i}', c, 1e12) for i, c in enumerate(caps)]
        ep = ExecPlan.from_plan(planner.plan(model, devs), head_dim=2, d_model=32)
        mesh = make_mesh_compat((8,), ('model',))
        assert not ep.is_even, ep.describe()

        vocab, n_layers = 50, 3
        layers = hmp.init_stack_params(jax.random.PRNGKey(0), n_layers, 32, 16, 64)
        emb = jax.random.normal(jax.random.PRNGKey(7), (vocab, 32)) * 0.5
        exe = GalaxyHMPExecutor(layers, emb, ep, mesh, overlap=True)
        assert exe.prompt_pad_multiple == 8 and exe.supports_paged

        # mixed prompt lengths (11, 11, 8, 4): lengths 8 and 4 share the
        # padded-8 wave bucket, so the wave path also runs mixed-depth decode
        prompts = [[1,2,3,4,5,6,7,8,9,10,11], [4,7,1,9,2,8,3,6,5,10,12],
                   [3,1,4,1,5,9,2,6], [2,7,1,8]]

        def run(scheduler):
            eng = ServingEngine(executor=exe, max_batch=3, max_len=24,
                                scheduler=scheduler, page_size=8)
            for i, pr in enumerate(prompts):
                eng.submit(Request(uid=i, prompt=list(pr), max_new_tokens=3 + i))
            return {r.uid: r.output for r in eng.run()}, eng.stats

        wave, wave_stats = run('wave')
        cont, cont_stats = run('continuous')
        assert cont == wave, (cont, wave)
        assert cont_stats['decode_steps'] <= wave_stats['decode_steps']

        for uid, pr in enumerate(prompts):
            toks = list(pr)
            for _ in range(3 + uid):
                x = emb[jnp.asarray([toks])]
                y = hmp.reference_stack(layers, x)
                toks.append(int(jnp.argmax(y[:, -1] @ emb.T, -1)[0]))
            assert cont[uid] == toks[len(pr):], (uid, cont[uid], toks[len(pr):])
            print('request', uid, 'tokens ok', cont[uid])
        print('continuous == wave == reference;',
              cont_stats['decode_steps'], 'vs', wave_stats['decode_steps'], 'steps')
    """)


def test_uneven_seq_plan_matches_reference():
    """Acceptance (mirrors the uneven-head case): ragged sequence tiles on
    4- and 8-device meshes — hmp / hmp_ring under uneven seq_shares match
    reference_layer for dividing and non-dividing lengths."""
    run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import hmp
        from repro.core.execplan import ExecPlan
        from repro.launch.mesh import make_mesh_compat

        cases = [
            (ExecPlan(heads=(6, 4, 4, 2), columns=(24, 16, 16, 8), head_dim=2,
                      d_model=32, seq_shares=(3.0, 2.0, 2.0, 1.0)),
             make_mesh_compat((4,), ('model',), devices=jax.devices()[:4])),
            (ExecPlan(heads=(3, 2, 2, 1, 4, 1, 2, 1),
                      columns=(12, 8, 8, 4, 16, 4, 8, 4), head_dim=2,
                      d_model=32,
                      seq_shares=(3.0, 2.0, 2.0, 1.0, 4.0, 0.0, 2.0, 3.0)),
             make_mesh_compat((8,), ('model',))),
        ]
        p = hmp.init_layer_params(jax.random.PRNGKey(0), 32, 16, 64)
        for ep, mesh in cases:
            assert ep.uneven_seq, ep.describe()
            for s in (16, 13):
                lay = ep.seq_layout(s)
                x = jax.random.normal(jax.random.PRNGKey(1), (2, s, 32)) * 0.5
                ref = hmp.reference_layer(p, x)
                xp = lay.scatter(x)
                for overlap in (False, True):
                    y = hmp.hmp_layer(p, xp, mesh, overlap=overlap, plan=ep,
                                      seq=s)
                    err = float(jnp.abs(lay.gather(y) - ref).max())
                    assert err < 2e-5, (ep.num_devices, s, overlap, err)
                    print(ep.num_devices, 'devs seq', s, 'overlap', overlap,
                          'ok', err)
    """)


def test_uneven_seq_serving_acceptance():
    """ISSUE acceptance: tiles [48, 32, 32, 16] on a 3:2:2:1 cluster with
    one slow link — prefill + decode through GalaxyHMPExecutor produce
    greedy tokens exactly matching the full-context reference, and the
    simulator scores the bandwidth-aware split below the equal split."""
    run_multidevice("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import hmp
        from repro.core.execplan import ExecPlan
        from repro.launch.mesh import make_mesh_compat
        from repro.serving import GalaxyHMPExecutor, Request, ServingEngine

        ep = ExecPlan(heads=(6, 4, 4, 2), columns=(24, 16, 16, 8), head_dim=2,
                      d_model=32, seq_shares=(3.0, 2.0, 2.0, 1.0))
        assert ep.seq_tiles(128) == (48, 32, 32, 16), ep.seq_tiles(128)
        mesh = make_mesh_compat((4,), ('model',))

        vocab, n_layers = 50, 3
        layers = hmp.init_stack_params(jax.random.PRNGKey(0), n_layers, 32, 16, 64)
        emb = jax.random.normal(jax.random.PRNGKey(7), (vocab, 32)) * 0.5
        exe = GalaxyHMPExecutor(layers, emb, ep, mesh, overlap=True)
        prompts = [[1,2,3,4,5,6,7,8,9,10,11], [4,7,1,9,2,8,3,6,5,10,12],
                   [3,1,4,1,5,9,2,6], [2,7,1,8]]

        def run(scheduler):
            eng = ServingEngine(executor=exe, max_batch=3, max_len=24,
                                scheduler=scheduler, page_size=8)
            for i, pr in enumerate(prompts):
                eng.submit(Request(uid=i, prompt=list(pr), max_new_tokens=3 + i))
            return {r.uid: r.output for r in eng.run()}

        wave, cont = run('wave'), run('continuous')
        assert wave == cont, (wave, cont)
        for uid, pr in enumerate(prompts):
            toks = list(pr)
            for _ in range(3 + uid):
                y = hmp.reference_stack(layers, emb[jnp.asarray([toks])])
                toks.append(int(jnp.argmax(y[:, -1] @ emb.T, -1)[0]))
            assert cont[uid] == toks[len(pr):], (uid, cont[uid], toks[len(pr):])
            print('request', uid, 'tokens ok', cont[uid])

        # simulator half of the acceptance: bandwidth-aware < equal
        from repro.configs import get_config
        from repro.core import costmodel
        from repro.core.profiler import AnalyticProfiler
        from repro.core.simulator import simulate_execplan
        cfg = dataclasses.replace(get_config('distilbert'), num_layers=1)
        caps = [3.0, 2.0, 2.0, 1.0]
        devices = [costmodel.DeviceSpec(f'e{i}', flops=c * 7.1e9, mem_bw=4.0e9,
                                        memory_budget=1.5e9)
                   for i, c in enumerate(caps)]
        links = [costmodel.mbps(1000), costmodel.mbps(1000),
                 costmodel.mbps(100), costmodel.mbps(1000)]
        prof = AnalyticProfiler(cfg, 128)
        ep_eq = ExecPlan.from_plan(prof.plan(devices), head_dim=cfg.head_dim,
                                   d_model=cfg.d_model)
        ep_bw = ExecPlan.from_plan(prof.plan(devices, links=links),
                                   head_dim=cfg.head_dim, d_model=cfg.d_model)
        assert ep_bw.uneven_seq and not ep_eq.uneven_seq
        r_eq = simulate_execplan(ep_eq, cfg, devices, links, 128, overlap=True)
        r_bw = simulate_execplan(ep_bw, cfg, devices, links, 128, overlap=True)
        assert r_bw.latency < r_eq.latency, (r_bw.latency, r_eq.latency)
        print(f'sim: aware {r_bw.latency*1e3:.1f}ms < equal '
              f'{r_eq.latency*1e3:.1f}ms')
    """, devices=4)


def test_overlap_transport_serving_acceptance():
    """ISSUE acceptance: bucketed ragged transport + double-buffered tile
    overlap through the full serving stack — a 4-device uneven plan serves
    with ``transport='bucketed', double_buffer=True`` on both schedulers,
    greedy tokens pinned equal to the padded-transport executor and a
    full-context reference recompute, and the executor's plan confirms the
    transport actually sheds wire rows."""
    run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import hmp
        from repro.core.execplan import ExecPlan
        from repro.launch.mesh import make_mesh_compat
        from repro.serving import GalaxyHMPExecutor, Request, ServingEngine

        # uneven on every axis: heads, columns, and sequence tiles
        ep = ExecPlan(heads=(6, 4, 4, 2), columns=(24, 16, 16, 8), head_dim=2,
                      d_model=32, seq_shares=(3.0, 2.0, 2.0, 1.0))
        mesh = make_mesh_compat((4,), ('model',))
        vocab, n_layers = 50, 3
        layers = hmp.init_stack_params(jax.random.PRNGKey(0), n_layers, 32, 16, 64)
        emb = jax.random.normal(jax.random.PRNGKey(7), (vocab, 32)) * 0.5
        prompts = [[1,2,3,4,5,6,7,8,9,10,11], [4,7,1,9,2,8,3,6,5,10,12],
                   [3,1,4,1,5,9,2,6], [2,7,1,8]]

        def serve(exe, scheduler):
            eng = ServingEngine(executor=exe, max_batch=3, max_len=24,
                                scheduler=scheduler, page_size=8)
            for i, pr in enumerate(prompts):
                eng.submit(Request(uid=i, prompt=list(pr), max_new_tokens=3 + i))
            return {r.uid: r.output for r in eng.run()}

        exe_pad = GalaxyHMPExecutor(layers, emb, ep, mesh, overlap=True)
        exe_db = GalaxyHMPExecutor(layers, emb, ep, mesh, overlap=True,
                                   transport='bucketed', double_buffer=True)
        assert exe_db.plan.transport == 'bucketed' and exe_db.plan.double_buffer
        sched = exe_db.plan.ring_schedule(128)
        assert sched.total_wire_rows() < sched.padded_wire_rows(), \\
            'bucketed transport sheds no wire on this plan'

        runs = {(label, scheduler): serve(exe, scheduler)
                for label, exe in (('padded', exe_pad), ('bucketed_db', exe_db))
                for scheduler in ('wave', 'continuous')}
        first = runs[('padded', 'wave')]
        for key, out in runs.items():
            assert out == first, (key, out, first)

        # and the shared answer is the full-context greedy reference
        for uid, pr in enumerate(prompts):
            toks = list(pr)
            for _ in range(3 + uid):
                y = hmp.reference_stack(layers, emb[jnp.asarray([toks])])
                toks.append(int(jnp.argmax(y[:, -1] @ emb.T, -1)[0]))
            assert first[uid] == toks[len(pr):], (uid, first[uid], toks[len(pr):])
            print('request', uid, 'tokens ok', first[uid])
        print('wire rows', sched.total_wire_rows(), '/',
              sched.padded_wire_rows())
    """, devices=4)


def test_prefix_cache_serving_acceptance():
    """ISSUE acceptance on the Galaxy executor: greedy tokens with the
    shared-prefix KV cache on == cache off == chunked prefill ==
    full-context reference, on both schedulers, under an uneven
    (heads, columns, sequence) 3:2:2:1 4-device plan — with suffix-only
    prefill measured (computed == prompt - cached), >= 1 physical page
    shared across >= 2 concurrent slots, and the pool's refcount algebra
    verified by ``check()``."""
    run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import hmp
        from repro.core.execplan import ExecPlan
        from repro.launch.mesh import make_mesh_compat
        from repro.serving import GalaxyHMPExecutor, Request, ServingEngine

        ep = ExecPlan(heads=(6, 4, 4, 2), columns=(24, 16, 16, 8), head_dim=2,
                      d_model=32, seq_shares=(3.0, 2.0, 2.0, 1.0))
        mesh = make_mesh_compat((4,), ('model',))
        vocab, n_layers = 50, 2
        layers = hmp.init_stack_params(jax.random.PRNGKey(0), n_layers,
                                       32, 16, 64)
        emb = jax.random.normal(jax.random.PRNGKey(7), (vocab, 32)) * 0.5
        exe = GalaxyHMPExecutor(layers, emb, ep, mesh, overlap=True)

        sysp = list(range(1, 17))  # 16-token shared system prompt (2 pages)
        prompts = [sysp + [20 + i, 21, 22 + i, 23] for i in range(4)]

        def run(**kw):
            eng = ServingEngine(executor=exe, max_batch=3, max_len=40,
                                page_size=8, **kw)
            for i, pr in enumerate(prompts):
                eng.submit(Request(uid=i, prompt=list(pr),
                                   max_new_tokens=3 + i))
            return {r.uid: r.output for r in eng.run()}, eng

        base, eng0 = run(scheduler='continuous')
        wave, _ = run(scheduler='wave')
        on, eng1 = run(scheduler='continuous', prefix_cache=True)
        chunked, eng2 = run(scheduler='continuous', prefill_chunk=8)
        both, eng3 = run(scheduler='continuous', prefix_cache=True,
                         prefill_chunk=8)
        assert wave == base and on == base, (wave, on, base)
        assert chunked == base and both == base, (chunked, both, base)

        s1 = eng1.stats
        total_prompt = sum(len(p) for p in prompts)
        assert s1['cached_prefix_tokens'] > 0
        assert s1['prefill_tokens'] + s1['cached_prefix_tokens'] == total_prompt
        assert s1['peak_shared_pages'] >= 1, s1
        assert eng2.stats['prefill_chunks'] >= len(prompts)
        eng1.pool.check()
        print('suffix-only prefill:', s1['prefill_tokens'], 'of',
              total_prompt, '| shared pages:', s1['peak_shared_pages'],
              '| hits:', s1['prefix_hits'])

        # full-context reference: plain stacked layers, no paging/sharing
        for uid, pr in enumerate(prompts):
            toks = list(pr)
            for _ in range(3 + uid):
                y = hmp.reference_stack(layers, emb[jnp.asarray([toks])])
                toks.append(int(jnp.argmax(y[:, -1] @ emb.T, -1)[0]))
            assert on[uid] == toks[len(pr):], (uid, on[uid], toks[len(pr):])
        print('prefix cache on == off == chunked == wave == reference')
    """, devices=4)


def test_pallas_backend_serving_acceptance():
    """ISSUE acceptance: the pad-shedding pallas backend on an uneven
    (heads, columns, sequence) 3:2:2:1 plan — greedy serving tokens through
    ``compute_backend="pallas"`` equal the padded-XLA oracle equal the
    full-context reference, on both schedulers; layer outputs agree across
    backends for dividing and non-dividing lengths."""
    run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import hmp
        from repro.core.execplan import ExecPlan
        from repro.launch.mesh import make_mesh_compat
        from repro.serving import GalaxyHMPExecutor, Request, ServingEngine

        ep = ExecPlan(heads=(6, 4, 4, 2), columns=(24, 16, 16, 8), head_dim=2,
                      d_model=32, seq_shares=(3.0, 2.0, 2.0, 1.0))
        mesh = make_mesh_compat((4,), ('model',))

        # layer: pallas == xla == reference on ragged + dense lengths
        p = hmp.init_layer_params(jax.random.PRNGKey(0), 32, 16, 64)
        for s in (16, 13):
            lay = ep.seq_layout(s)
            x = jax.random.normal(jax.random.PRNGKey(1), (2, s, 32)) * 0.5
            ref = hmp.reference_layer(p, x)
            xp = lay.scatter(x)
            for overlap in (False, True):
                y_x = hmp.hmp_layer(p, xp, mesh, overlap=overlap, plan=ep,
                                    seq=s)
                y_p = hmp.hmp_layer(p, xp, mesh, overlap=overlap,
                                    plan=ep.with_backend('pallas'), seq=s)
                e_ref = float(jnp.abs(lay.gather(y_p) - ref).max())
                e_xla = float(jnp.abs(y_p - y_x).max())
                assert e_ref < 2e-5 and e_xla < 1e-4, (s, overlap, e_ref, e_xla)
                print('layer seq', s, 'overlap', overlap, 'ok', e_ref, e_xla)

        # serving: greedy tokens pallas == xla == full-context reference
        vocab, n_layers = 50, 3
        layers = hmp.init_stack_params(jax.random.PRNGKey(0), n_layers, 32, 16, 64)
        emb = jax.random.normal(jax.random.PRNGKey(7), (vocab, 32)) * 0.5
        prompts = [[1,2,3,4,5,6,7,8,9,10,11], [4,7,1,9,2,8,3,6,5,10,12],
                   [3,1,4,1,5,9,2,6], [2,7,1,8]]

        def run(backend, scheduler):
            exe = GalaxyHMPExecutor(layers, emb, ep, mesh, overlap=True,
                                    compute_backend=backend)
            assert exe.plan.compute_backend == backend
            eng = ServingEngine(executor=exe, max_batch=3, max_len=24,
                                scheduler=scheduler, page_size=8)
            for i, pr in enumerate(prompts):
                eng.submit(Request(uid=i, prompt=list(pr), max_new_tokens=3 + i))
            return {r.uid: r.output for r in eng.run()}

        out = {(b, s): run(b, s) for b in ('xla', 'pallas')
               for s in ('wave', 'continuous')}
        assert out['pallas', 'wave'] == out['xla', 'wave']
        assert out['pallas', 'continuous'] == out['xla', 'continuous']
        assert out['pallas', 'continuous'] == out['pallas', 'wave']

        for uid, pr in enumerate(prompts):
            toks = list(pr)
            for _ in range(3 + uid):
                y = hmp.reference_stack(layers, emb[jnp.asarray([toks])])
                toks.append(int(jnp.argmax(y[:, -1] @ emb.T, -1)[0]))
            assert out['pallas', 'continuous'][uid] == toks[len(pr):], (
                uid, out['pallas', 'continuous'][uid], toks[len(pr):])
            print('request', uid, 'pallas tokens ok',
                  out['pallas', 'continuous'][uid])
        print('pallas == xla == reference on both schedulers')
    """, devices=4)


def test_ring_tile_size_validation():
    """Non-dividing sequences raise ValueError at trace time (not a bare
    assert), for both ring and sync reduce-scatter paths."""
    run_multidevice("""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core import hmp, ring
        from repro.core.execplan import ExecPlan
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4,), ('model',))

        h = jax.random.normal(jax.random.PRNGKey(0), (1, 30, 16))  # 30 % 4 != 0
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        for fn in (ring.matmul_ring_reducescatter, ring.sync_matmul_reducescatter):
            try:
                shard_map(lambda hl, wl, f=fn: f(hl, wl, 'model'), mesh=mesh,
                          in_specs=(P(None, None, 'model'), P('model', None)),
                          out_specs=P(None, 'model', None))(h, w)
            except ValueError as e:
                print('ok:', type(e).__name__)
            else:
                raise SystemExit('expected ValueError for non-dividing seq')

        # a schedule whose pad_tile disagrees with the shapes is rejected
        h2 = jax.random.normal(jax.random.PRNGKey(0), (1, 32, 16))
        bad4 = ring.RingSchedule.dense(4, 4)
        try:
            shard_map(lambda hl, wl: ring.matmul_ring_reducescatter(
                          hl, wl, 'model', schedule=bad4), mesh=mesh,
                      in_specs=(P(None, None, 'model'), P('model', None)),
                      out_specs=P(None, 'model', None))(h2, w)
        except ValueError as e:
            print('ok:', type(e).__name__)
        else:
            raise SystemExit('expected ValueError for wrong tile size')

        # hmp_layer under a plan rejects a non-dividing sequence up front
        ep = ExecPlan.even(4, num_heads=8, d_ff=32, head_dim=4, d_model=32)
        p = hmp.init_layer_params(jax.random.PRNGKey(0), 32, 8, 32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 30, 32))
        try:
            hmp.hmp_layer(p, x, mesh, plan=ep)
        except ValueError as e:
            print('ok:', type(e).__name__)
        else:
            raise SystemExit('expected ValueError from hmp_layer')
    """, devices=4)


def test_simulator_scores_the_executed_plan():
    """simulate_execplan consumes the same ExecPlan the executor runs and
    exposes the padding premium of SPMD execution."""
    import dataclasses

    from repro.configs import get_config
    from repro.core import costmodel
    from repro.core.simulator import simulate_execplan

    cfg = dataclasses.replace(get_config("distilbert"), num_layers=1)
    caps = [3.0, 2.0, 2.0, 1.0]
    devices = [
        costmodel.DeviceSpec(f"e{i}", flops=c * 7.1e9, mem_bw=4.0e9,
                             memory_budget=1.5e9)
        for i, c in enumerate(caps)
    ]
    link = costmodel.mbps(1000)
    from repro.core.profiler import AnalyticProfiler

    prof = AnalyticProfiler(cfg, 128)
    pl = planner.plan(prof.model_profile(), prof.device_profiles(devices))
    assert pl.feasible
    ep = ExecPlan.from_plan(pl, head_dim=cfg.head_dim, d_model=cfg.d_model)
    assert not ep.is_even

    sync = simulate_execplan(ep, cfg, devices, link, 128, overlap=False)
    ring_ = simulate_execplan(ep, cfg, devices, link, 128, overlap=True)
    padded = simulate_execplan(ep, cfg, devices, link, 128, overlap=True,
                               padded=True)
    assert 0 < ring_.latency <= sync.latency
    # padding makes every device run the straggler's share: never faster
    assert padded.latency >= ring_.latency - 1e-12
    with pytest.raises(ValueError, match="devices"):
        simulate_execplan(ep, cfg, devices[:2], link, 128)
