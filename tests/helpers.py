"""Shared test utilities."""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced


def smoke_cfg(arch: str):
    return reduced(get_config(arch))


def make_inputs(cfg, batch=2, seq=16, key=0):
    """Model inputs for a reduced config (tokens or stub embeddings)."""
    kw = {}
    if cfg.input_mode == "token":
        kw["tokens"] = jax.random.randint(
            jax.random.PRNGKey(key), (batch, seq), 0, cfg.vocab_size
        )
    else:
        kw["embeds"] = (
            jax.random.normal(jax.random.PRNGKey(key), (batch, seq, cfg.d_model)) * 0.1
        )
    if cfg.num_image_tokens:
        kw["img_embeds"] = (
            jax.random.normal(
                jax.random.PRNGKey(key + 1), (batch, cfg.num_image_tokens, cfg.d_model)
            )
            * 0.1
        )
    return kw


def make_batch(cfg, batch=2, seq=16, key=0):
    kw = make_inputs(cfg, batch, seq, key)
    labels = jax.random.randint(jax.random.PRNGKey(key + 2), (batch, seq), 0, cfg.vocab_size)
    if cfg.num_codebooks > 1:
        labels = jnp.stack([labels] * cfg.num_codebooks, axis=-1)
    kw["labels"] = labels
    return kw
