"""Block-level unit + property tests: MoE dispatch, RG-LRU scan, xLSTM
chunked-vs-recurrent, attention masks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.attention import _window_cache_positions, causal_window_mask
from repro.models.moe import moe_apply, moe_capacity
from repro.models.rglru import rglru_scan
from repro.models.xlstm import mlstm_chunked, mlstm_scan

from helpers import smoke_cfg


# --- MoE ----------------------------------------------------------------------

def _moe_params(cfg, key=0):
    from repro.models import init_params
    p = init_params(cfg, jax.random.PRNGKey(key))
    # grouped params are stacked along a leading group dim: take group 0
    return jax.tree.map(lambda x: x[0], p["groups"]["b0_attn"]["moe"])


def test_moe_capacity_formula():
    cfg = smoke_cfg("olmoe-1b-7b")
    assert moe_capacity(cfg, 64) == int(2.0 * cfg.experts_per_token * 64 / cfg.num_experts)
    assert moe_capacity(cfg, 1) >= 1


def test_moe_no_drops_at_high_capacity():
    cfg = smoke_cfg("olmoe-1b-7b")
    p = _moe_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.1
    out, aux = moe_apply(p, x, cfg, capacity_factor=8.0)
    assert out.shape == x.shape
    assert float(aux["moe_drop_frac"]) < 1e-6
    assert float(aux["moe_lb_loss"]) > 0


def test_moe_combine_weights_convex():
    """Per-token combine weights sum to ~1 when nothing is dropped, so the
    output magnitude tracks the experts' outputs."""
    cfg = smoke_cfg("olmoe-1b-7b")
    p = _moe_params(cfg)
    x = jnp.ones((1, 8, cfg.d_model)) * 0.05
    out_hi, _ = moe_apply(p, x, cfg, capacity_factor=8.0)
    assert np.isfinite(np.asarray(out_hi)).all()


def test_moe_padded_experts_never_selected():
    cfg = dataclasses.replace(smoke_cfg("olmoe-1b-7b"), num_experts=3,
                              experts_per_token=2)
    from repro.models import init_params
    p = jax.tree.map(
        lambda x: x[0], init_params(cfg, jax.random.PRNGKey(0))["groups"]["b0_attn"]["moe"]
    )
    assert p["we_up"].shape[0] == 3  # <16 experts: no padding
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model)) * 0.1
    out, aux = moe_apply(p, x, cfg)
    assert np.isfinite(np.asarray(out)).all()


# --- RG-LRU -------------------------------------------------------------------

def test_rglru_scan_matches_loop():
    b, s, w = 2, 17, 8
    a = jax.random.uniform(jax.random.PRNGKey(0), (b, s, w), minval=0.5, maxval=0.99)
    bb = jax.random.normal(jax.random.PRNGKey(1), (b, s, w))
    h0 = jax.random.normal(jax.random.PRNGKey(2), (b, w))
    h_seq, h_last = rglru_scan(a, bb, h0)
    h = h0
    for t in range(s):
        h = a[:, t] * h + bb[:, t]
        np.testing.assert_allclose(np.asarray(h_seq[:, t]), np.asarray(h),
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h), atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(s=st.integers(1, 33), seed=st.integers(0, 1000))
def test_property_rglru_decay_bounded(s, seed):
    """With |a|<1 and bounded inputs the recurrence never blows up."""
    k = jax.random.PRNGKey(seed)
    a = jax.random.uniform(k, (1, s, 4), minval=0.0, maxval=0.999)
    bb = jax.random.normal(jax.random.fold_in(k, 1), (1, s, 4))
    h_seq, _ = rglru_scan(a, bb, None)
    assert np.isfinite(np.asarray(h_seq)).all()
    assert np.abs(np.asarray(h_seq)).max() < 1e3


# --- xLSTM ---------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    nc=st.integers(1, 4),
    chunk=st.sampled_from([4, 8]),
    seed=st.integers(0, 100),
)
def test_property_mlstm_chunked_equals_scan(nc, chunk, seed):
    b, nh, dk, dv = 1, 2, 8, 8
    s = nc * chunk
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, s, nh, dk))
    k = jax.random.normal(ks[1], (b, s, nh, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, s, nh, dv))
    i_raw = jax.random.normal(ks[3], (b, s, nh))
    f_raw = jax.random.normal(ks[4], (b, s, nh)) + 1.0
    state = (jnp.zeros((b, nh, dv, dk)), jnp.zeros((b, nh, dk)),
             jnp.full((b, nh), -1e30))
    h1, (c1, n1, m1) = mlstm_scan(q, k, v, i_raw, f_raw, state)
    h2, (c2, n2, m2) = mlstm_chunked(q, k, v, i_raw, f_raw, state, chunk)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)


def test_mlstm_chunked_carry_chains():
    """Chunked state carries across two separate calls == one long call."""
    b, s, nh, dk, dv, chunk = 1, 32, 2, 8, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    q = jax.random.normal(ks[0], (b, s, nh, dk))
    k = jax.random.normal(ks[1], (b, s, nh, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, s, nh, dv))
    i_raw = jax.random.normal(ks[3], (b, s, nh))
    f_raw = jax.random.normal(ks[4], (b, s, nh)) + 1.0
    st0 = (jnp.zeros((b, nh, dv, dk)), jnp.zeros((b, nh, dk)),
           jnp.full((b, nh), -1e30))
    h_full, _ = mlstm_chunked(q, k, v, i_raw, f_raw, st0, chunk)
    half = s // 2
    h1, st1 = mlstm_chunked(q[:, :half], k[:, :half], v[:, :half],
                            i_raw[:, :half], f_raw[:, :half], st0, chunk)
    h2, _ = mlstm_chunked(q[:, half:], k[:, half:], v[:, half:],
                          i_raw[:, half:], f_raw[:, half:], st1, chunk)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([h1, h2], axis=1)), np.asarray(h_full), atol=1e-4
    )


# --- attention masks -------------------------------------------------------------

def test_window_cache_positions():
    # window 4, after writing position 5: slots hold t = [4, 5, 2, 3]
    pos = _window_cache_positions(jnp.int32(5), 4)
    assert pos.tolist() == [4, 5, 2, 3]
    # early: position 1 -> slots [0, 1, empty, empty]
    pos = _window_cache_positions(jnp.int32(1), 4)
    assert pos.tolist() == [0, 1, -1, -1]


def test_causal_window_mask_semantics():
    q_pos = jnp.array([[3]])
    k_pos = jnp.arange(6)
    m = causal_window_mask(q_pos, k_pos, window=0)[0, 0, 0]
    assert m.tolist() == [True, True, True, True, False, False]
    m = causal_window_mask(q_pos, k_pos, window=2)[0, 0, 0]
    assert m.tolist() == [False, False, True, True, False, False]
