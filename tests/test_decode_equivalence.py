"""System invariant: prefill + token-by-token decode reproduces the full
forward pass for every architecture family (KV caches, rolling windows,
recurrent states, MoE dispatch, cross-attention caches)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS
from repro.models import apply_model, init_params
from repro.serving.kvcache import make_cache

from helpers import make_inputs, smoke_cfg

TOL = 2e-5


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = smoke_cfg(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    kw = make_inputs(cfg, batch=b, seq=s)
    img = {k: v for k, v in kw.items() if k == "img_embeds"}
    main_key = "tokens" if "tokens" in kw else "embeds"
    full = kw[main_key]

    ref, _, _ = apply_model(params, cfg, mode="train", **kw)

    s0 = s - 3
    cache = make_cache(cfg, b, s)
    pl, cache, _ = apply_model(
        params, cfg, mode="prefill", cache=cache,
        **{main_key: full[:, :s0]}, **img,
    )
    np.testing.assert_allclose(np.asarray(pl), np.asarray(ref[:, :s0]), atol=TOL)

    for t in range(s0, s):
        pos = jnp.broadcast_to(jnp.int32(t), (b, 1))
        dl, cache, _ = apply_model(
            params, cfg, mode="decode", cache=cache,
            cache_index=jnp.int32(t), positions=pos,
            **{main_key: full[:, t : t + 1]}, **img,
        )
        np.testing.assert_allclose(np.asarray(dl[:, 0]), np.asarray(ref[:, t]), atol=TOL)


def test_sliding_window_decode_past_window():
    """Rolling-buffer decode stays exact after positions wrap the window."""
    import dataclasses

    cfg = dataclasses.replace(smoke_cfg("qwen1.5-0.5b"), window=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 1, 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)

    ref, _, _ = apply_model(params, cfg, mode="train", tokens=toks)

    s0 = 4
    cache = make_cache(cfg, b, s)
    assert cache["groups"]["b0_attn"]["k"].shape[2] == 8  # W slots exactly
    _, cache, _ = apply_model(params, cfg, mode="prefill", cache=cache, tokens=toks[:, :s0])
    for t in range(s0, s):
        pos = jnp.broadcast_to(jnp.int32(t), (b, 1))
        dl, cache, _ = apply_model(
            params, cfg, mode="decode", cache=cache, cache_index=jnp.int32(t),
            positions=pos, tokens=toks[:, t : t + 1],
        )
        np.testing.assert_allclose(
            np.asarray(dl[:, 0]), np.asarray(ref[:, t]), atol=TOL,
            err_msg=f"divergence at position {t} (window wrap)"
        )


def test_prefill_longer_than_window():
    """Prefill with S > window keeps only the last W keys, matching the
    windowed full forward."""
    import dataclasses

    cfg = dataclasses.replace(smoke_cfg("qwen1.5-0.5b"), window=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    ref, _, _ = apply_model(params, cfg, mode="train", tokens=toks)
    cache = make_cache(cfg, b, s + 2)
    _, cache, _ = apply_model(params, cfg, mode="prefill", cache=cache, tokens=toks)
    dl, _, _ = apply_model(
        params, cfg, mode="decode", cache=cache, cache_index=jnp.int32(s),
        positions=jnp.full((b, 1), s, jnp.int32),
        tokens=jax.random.randint(jax.random.PRNGKey(2), (b, 1), 0, cfg.vocab_size),
    )
    assert np.isfinite(np.asarray(dl)).all()
