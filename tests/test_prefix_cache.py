"""Shared-prefix KV cache: refcounted pool algebra, radix-tree semantics
(incl. the hypothesis leak/double-free property test), and engine-level
greedy-token equality with the cache and chunked prefill on vs off."""
import jax
import numpy as np
import pytest

from repro.models import init_params
from repro.serving import PagedKVPool, PrefixCache, Request, ServingEngine
from repro.serving.kvpool import NULL_PAGE

from helpers import smoke_cfg


# --- refcounted pool ----------------------------------------------------------

def test_shared_pages_free_only_at_refcount_zero():
    pool = PagedKVPool(num_pages=12, page_size=4, num_slots=3, pages_per_slot=4)
    pool.admit(0, initial_positions=8, max_positions=12)
    shared = list(pool.block_table[0, :2])
    pool.admit(1, initial_positions=8, max_positions=12, shared_pages=shared)
    pool.check()
    assert pool.shared_page_count() == 2
    assert all(pool.refcount[p] == 2 for p in shared)
    # slot 1 retires: shared pages stay allocated (slot 0 still reads them)
    pool.retire(1)
    pool.check()
    assert all(pool.refcount[p] == 1 for p in shared)
    assert pool.shared_page_count() == 0
    pool.retire(0)
    pool.check()
    assert pool.free_pages == 11  # everything back


def test_shared_admission_needs_fewer_new_pages():
    pool = PagedKVPool(num_pages=6, page_size=4, num_slots=2, pages_per_slot=4)
    pool.admit(0, initial_positions=16, max_positions=16)  # 4 of 5 pages
    shared = list(pool.block_table[0, :3])
    assert not pool.can_admit(16)  # cold: needs 4, 1 available
    assert pool.can_admit(16, shared=3)  # warm: only the tail page is new
    pool.admit(1, initial_positions=16, max_positions=16, shared_pages=shared)
    pool.check()
    with pytest.raises(ValueError, match="already active"):
        pool.admit(1, 4, 4)
    pool.retire(0)
    pool.retire(1)
    pool.check()


def test_pin_keeps_page_alive_across_retire():
    pool = PagedKVPool(num_pages=4, page_size=2, num_slots=1, pages_per_slot=3)
    pool.admit(0, 4, 4)
    page = int(pool.block_table[0, 0])
    pool.pin(page)
    pool.check()
    pool.retire(0)
    pool.check()
    assert pool.refcount[page] == 1 and pool.free_pages == 2
    assert pool.unpin(page)  # last reference -> freed
    pool.check()
    assert pool.free_pages == 3
    with pytest.raises(ValueError):
        pool.unpin(page)  # double-unpin is a bug, loudly


def test_release_guards():
    pool = PagedKVPool(num_pages=4, page_size=2, num_slots=2, pages_per_slot=2)
    with pytest.raises(ValueError):
        pool.pin(1)  # unallocated
    pool.admit(0, 2, 2)
    with pytest.raises(ValueError):
        pool.admit(1, 2, 2, shared_pages=[NULL_PAGE])
    with pytest.raises(ValueError):
        pool.admit(1, 2, 4, shared_pages=list(pool.block_table[0, :1]) * 2)


# --- radix tree ---------------------------------------------------------------

def _pool(num_pages=32, page_size=4, num_slots=4, pages_per_slot=8):
    return PagedKVPool(num_pages, page_size, num_slots, pages_per_slot)


def test_lookup_is_page_aligned_and_proper():
    pool = _pool()
    pc = PrefixCache(pool)
    prompt = list(range(10))  # 2 full pages + 2 tokens
    pool.admit(0, 12, 12)
    pc.insert(prompt, pool.block_table[0])
    assert len(pc) == 2  # only the full pages entered the tree

    # exact full-page prefix match
    pages, cached = pc.lookup(list(range(8)) + [99])
    assert cached == 8 and len(pages) == 2
    # a prompt that *is* the cached prefix must keep its last token
    # computable: the match is capped at len(prompt) - 1 and re-floored
    pages, cached = pc.lookup(list(range(8)))
    assert cached == 4 and len(pages) == 1
    # diverging second page: only the first matches
    pages, cached = pc.lookup([0, 1, 2, 3, 9, 9, 9, 9, 5])
    assert cached == 4 and len(pages) == 1
    # grain coarser than a page floors the match
    pc8 = PrefixCache(_pool(), grain=8)
    with pytest.raises(ValueError):
        PrefixCache(_pool(), grain=6)  # not a page multiple
    pool2 = pc8.pool
    pool2.admit(0, 12, 12)
    pc8.insert(prompt, pool2.block_table[0])
    pages, cached = pc8.lookup(list(range(8)) + [99])
    assert cached == 8 and len(pages) == 2
    pages, cached = pc8.lookup([0, 1, 2, 3, 9, 9, 9, 9, 5])
    assert cached == 0 and pages == []


def test_insert_skips_existing_nodes():
    pool = _pool()
    pc = PrefixCache(pool)
    prompt = list(range(8))
    pool.admit(0, 8, 8)
    assert pc.insert(prompt, pool.block_table[0]) == 2
    first_pages = pc.held_pages()
    # a second request with the same prefix keeps its private duplicates
    # out of the tree (the first to finish wins)
    pool.admit(1, 8, 8)
    assert pc.insert(prompt, pool.block_table[1]) == 0
    assert sorted(pc.held_pages()) == sorted(first_pages)
    pool.check()


def test_evict_lru_leaves_only_idle_pages():
    pool = _pool(num_pages=16)
    pc = PrefixCache(pool)
    pool.admit(0, 16, 16)
    pc.insert(list(range(16)), pool.block_table[0])
    assert len(pc) == 4
    # slot 0 still reads every page: nothing is evictable
    assert pc.evict(10) == 0 and len(pc) == 4
    pool.retire(0)
    pool.check()
    # now the tree is the only holder: eviction cascades leaf -> root
    assert pc.evict(2) == 2 and len(pc) == 2
    assert pc.evict(10) == 2 and len(pc) == 0
    pool.check()
    assert pool.free_pages == 15
    assert pc.stats()["evicted_pages"] == 4


def test_overlapping_prefixes_share_the_common_pages():
    pool = _pool()
    pc = PrefixCache(pool)
    a = list(range(12))
    b = list(range(8)) + [50, 51, 52, 53]  # shares 2 of 3 pages with a
    pool.admit(0, 12, 12)
    pc.insert(a, pool.block_table[0])
    pages_b, cached_b = pc.lookup(b)
    assert cached_b == 8
    pool.admit(1, 12, 12, shared_pages=pages_b)
    pc.insert(b, pool.block_table[1])
    pool.check()
    # tree: 3 nodes for a + 1 divergent third page for b
    assert len(pc) == 4
    pages_a2, cached_a2 = pc.lookup(a + [99])
    assert cached_a2 == 12
    assert pages_a2[:2] == pages_b[:2]


# --- hypothesis: random overlapping admit/retire never leaks ------------------

def test_random_prefix_lifecycle_never_leaks_or_double_frees():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=50, deadline=None)
    @hypothesis.given(
        ops=st.lists(
            st.tuples(
                st.sampled_from(["admit", "grow", "retire", "evict"]),
                st.integers(0, 3),    # slot
                st.integers(0, 2),    # base prompt family
                st.integers(0, 20),   # length / position argument
            ),
            max_size=50,
        ),
        page_size=st.integers(1, 4),
        num_pages=st.integers(4, 40),
    )
    def run(ops, page_size, num_pages):
        pool = PagedKVPool(num_pages, page_size, num_slots=4, pages_per_slot=8)
        pc = PrefixCache(pool)
        bases = [[100 + f] * 32 for f in range(3)]  # overlapping families
        live = {}
        for op, slot, fam, arg in ops:
            if op == "admit" and not pool.active[slot]:
                # family prefix + a unique tail: prompts overlap page-wise
                prompt = bases[fam][: max(arg, 1)] + [slot, fam, arg]
                s = len(prompt)
                s_pad = -(-s // page_size) * page_size
                limit = 1 + arg % 3
                maxp = s_pad + limit
                if pool.pages_for(maxp) > pool.pages_per_slot:
                    continue
                pages, cached = pc.lookup(prompt)
                if not pool.can_admit(maxp, shared=len(pages)):
                    pc.evict(pool.pages_for(maxp) - len(pages)
                             - pool.available)
                    pages, cached = pc.lookup(prompt)
                    if not pool.can_admit(maxp, shared=len(pages)):
                        continue
                pool.admit(slot, initial_positions=s_pad,
                           max_positions=maxp, shared_pages=pages)
                pc.insert(prompt, pool.block_table[slot])
                live[slot] = (s, maxp)
            elif op == "grow" and pool.active[slot]:
                s, maxp = live[slot]
                pool.ensure(slot, min(s + arg % 4, maxp - 1))
            elif op == "retire" and pool.active[slot]:
                pool.retire(slot)
                live.pop(slot)
            elif op == "evict":
                pc.evict(arg)
            pool.check()
        for slot in list(live):
            pool.retire(slot)
            pool.check()
        pc.clear()
        pool.check()
        assert pool.free_pages == num_pages - 1  # no leak, no double-free

    run()


# --- engine: cache on == cache off == chunked == wave == reference ------------

_SYS = [7, 7, 7] + list(range(50, 79))  # 32 tokens: 4 pages at page_size 8


def _requests():
    rng = np.random.default_rng(0)
    return [
        Request(uid=i,
                prompt=_SYS + [int(t) for t in rng.integers(1, 300, 5 + i)],
                max_new_tokens=6 if i % 2 else 12)
        for i in range(6)
    ]


def _run(params, cfg, **kw):
    eng = ServingEngine(params, cfg, max_batch=3, max_len=64, page_size=8, **kw)
    for r in _requests():
        eng.submit(r)
    done = eng.run()
    assert all(r.done for r in done) and len(done) == 6
    return {r.uid: r.output for r in done}, eng


def test_prefix_cache_and_chunked_prefill_match_baseline():
    """Greedy tokens: prefix cache on == off == chunked == both == wave,
    with suffix-only prefill measured (computed == prompt - cached) and at
    least one physical page shared across >=2 concurrent slots, refcounts
    verified by ``PagedKVPool.check()`` at every sharing admission."""
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    base, eng0 = _run(params, cfg, scheduler="continuous")
    wave, _ = _run(params, cfg, scheduler="wave")
    on, eng1 = _run(params, cfg, scheduler="continuous", prefix_cache=True)
    chunked, eng2 = _run(params, cfg, scheduler="continuous", prefill_chunk=8)
    both, eng3 = _run(params, cfg, scheduler="continuous", prefix_cache=True,
                      prefill_chunk=8)
    assert wave == base
    assert on == base
    assert chunked == base
    assert both == base

    # suffix-only prefill: computed tokens == prompt tokens - cached tokens
    total_prompt = sum(len(r.prompt) for r in _requests())
    s1 = eng1.stats
    assert s1["cached_prefix_tokens"] > 0
    assert s1["prefill_tokens"] + s1["cached_prefix_tokens"] == total_prompt
    assert eng0.stats["prefill_tokens"] == total_prompt
    # >= 1 physical page shared across >= 2 concurrent slots
    assert s1["peak_shared_pages"] >= 1
    assert s1["prefix_hits"] >= 1
    eng1.pool.check()
    # chunked prefill actually chunked
    assert eng2.stats["prefill_chunks"] > len(_requests())
    assert eng3.stats["prefill_chunks"] > 0
    # hit-rate stats surface through the engine
    assert eng1.prefix_stats is not None
    assert eng1.prefix_stats["hits"] >= 1


def test_prefix_cache_matches_full_context_reference():
    """Cache-on greedy tokens equal a manual full-context prefill+decode
    (no paging, no sharing) for a prefix-hitting request."""
    import jax.numpy as jnp

    from repro.models import apply_model
    from repro.serving import make_cache

    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    warm = Request(uid=0, prompt=_SYS + [1, 2, 3], max_new_tokens=4)
    hit = Request(uid=1, prompt=_SYS + [9, 8, 7, 6], max_new_tokens=5)

    eng = ServingEngine(params, cfg, max_batch=1, max_len=64, page_size=8,
                        scheduler="continuous", prefix_cache=True)
    eng.submit(warm)
    eng.submit(hit)
    done = {r.uid: r.output for r in eng.run()}
    assert eng.stats["prefix_hits"] >= 1  # the second request hit

    for req in (warm, hit):
        prompt = req.prompt
        toks = jnp.asarray([prompt], jnp.int32)
        cache = make_cache(cfg, 1, len(prompt) + req.max_new_tokens)
        logits, cache, _ = apply_model(params, cfg, mode="prefill",
                                       cache=cache, tokens=toks)
        out = []
        last = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        for t in range(req.max_new_tokens):
            out.append(int(last[0]))
            if t == req.max_new_tokens - 1:
                break
            idx = jnp.int32(len(prompt) + t)
            logits, cache, _ = apply_model(
                params, cfg, mode="decode", cache=cache, cache_index=idx,
                positions=jnp.full((1, 1), idx, jnp.int32),
                tokens=last[:, None],
            )
            last = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        assert done[req.uid] == out, req.uid


def test_prefix_flags_need_capable_executor_and_scheduler():
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(params, cfg, scheduler="wave", prefix_cache=True)
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=2))
    with pytest.raises(ValueError, match="continuous"):
        eng.run()
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingEngine(params, cfg, prefill_chunk=0)

    class NoChunk:
        supports_paged = True

    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingEngine(executor=NoChunk(), prefix_cache=True)
