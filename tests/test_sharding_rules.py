"""Logical-axis rule tables + shape-safe spec generation (the mechanism the
HMP layout is expressed through)."""
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh_compat
from repro.models.sharding import Rules, make_rules


def _mesh():
    return make_mesh_compat((1, 1), ("data", "model"))


def test_rules_dedup_mesh_axes():
    r = Rules({"seq": "model", "vocab": "model"}, None)
    spec = r.spec(("seq", "vocab"))
    assert spec == P("model", None)  # first use wins, no duplicate axis


def test_shape_safe_drops_nondividing():
    make_mesh_compat((1, 1), ("data", "model"))  # touch jax device state once
    # fake sizes via mapping against a mesh of known shape
    import numpy as np

    class FakeMesh:
        shape = {"data": 4, "model": 16}
        devices = np.empty((4, 16))

    r = Rules({"kv_heads": "model", "kv_seq": ("data", "model")}, FakeMesh())
    assert r.spec(("kv_heads",), shape=(8,)) == P(None)      # 8 % 16 != 0
    assert r.spec(("kv_heads",), shape=(32,)) == P("model")
    # tuple mapping keeps the dividing prefix
    assert r.spec(("kv_seq",), shape=(8,)) == P("data")      # 8 % 4 == 0, % 64 != 0
    assert r.spec(("kv_seq",), shape=(128,)) == P(("data", "model"))


def test_make_rules_modes():
    train = make_rules(None, "train")
    assert train.mapping["seq"] == "model"
    assert train.mapping["kv_seq"] is None
    decode = make_rules(None, "decode")
    assert decode.mapping["seq"] is None
    assert decode.mapping["kv_seq"] == "model"
    long = make_rules(None, "decode_long", batch_size=1)
    assert long.mapping["batch"] is None
    assert long.mapping["kv_seq"] == ("data", "model")
    mp = make_rules(None, "train", multi_pod=True)
    assert mp.mapping["batch"] == ("pod", "data")


def test_megatron_tp_baseline_rules():
    tp = make_rules(None, "train", hmp_sequence_parallel=False)
    assert tp.mapping["seq"] is None  # connective replicated (M-LM layout)
    assert tp.mapping["heads"] == "model"


def test_axis_size():
    import numpy as np

    class FakeMesh:
        shape = {"data": 4, "model": 16}
        devices = np.empty((4, 16))

    r = Rules({"batch": ("data",), "kv_seq": ("data", "model"), "x": None}, FakeMesh())
    assert r.axis_size("batch") == 4
    assert r.axis_size("kv_seq") == 64
    assert r.axis_size("x") == 1
    assert r.axis_size("missing") == 1
