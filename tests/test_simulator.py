"""Paper-claim validation: the calibrated simulator must land inside
honest bands around every number the Galaxy paper reports."""
import pytest

from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core import simulator as sim
from repro.core.simulator import strong_scaling, weak_scaling


def test_table1_on_device_latency():
    """§II-B Table I: DistilBert 0.37s / Bert-L 2.43s on Nano-M, seq 30."""
    for name, paper, tol in [("distilbert", 0.37, 0.15), ("bert-l", 2.43, 0.15)]:
        r = sim.simulate(get_config(name), [cm.jetson_nano("nano-m", 1.5)],
                         cm.mbps(125), 30, "local")
        assert abs(r.latency - paper) / paper < tol, (name, r.latency)


def test_table1_oom_pattern():
    dev = [cm.jetson_nano("nano-m", 1.5)]
    for name in ("gpt2-l", "opt-l", "opt-xl"):
        assert sim.simulate(get_config(name), dev, cm.mbps(125), 30, "local").oom


def test_table1_memory_footprints():
    """fp16 footprints: DistilBert ~130MB, Bert-L ~680MB, OPT-XL ~5.4GB."""
    for name, mb in [("distilbert", 130), ("bert-l", 680), ("gpt2-l", 1600), ("opt-xl", 5400)]:
        got = cm.model_memory_bytes(get_config(name)) / 1e6
        assert abs(got - mb) / mb < 0.30, (name, got)


@pytest.mark.parametrize(
    "model,env,paper_mlm",
    [
        ("distilbert", "A", 1.37), ("bert-l", "A", 1.36), ("bert-l", "B", 1.38),
        ("gpt2-l", "A", 1.31), ("gpt2-l", "B", 1.46),
        ("opt-l", "A", 1.26), ("opt-l", "B", 1.40), ("opt-l", "C", 1.43),
        ("opt-xl", "C", 1.28),
    ],
)
def test_table4_speedup_vs_megatron(model, env, paper_mlm):
    t = sim.speedup_table(get_config(model), cm.edge_env(env), cm.mbps(125), 284)
    got = t["megatron"]
    assert isinstance(got, float)
    assert got > 1.0, "Galaxy must beat Megatron-TP"
    assert abs(got - paper_mlm) < 0.35, (model, env, got, paper_mlm)


def test_table4_sp_oom_pattern():
    """SP replicates weights -> OOM for gpt2-l and larger (paper Table IV)."""
    for model in ("gpt2-l", "opt-l", "opt-xl"):
        t = sim.speedup_table(get_config(model), cm.edge_env("B"), cm.mbps(125), 284)
        assert t["sp"] in ("OOM", "GALAXY-OOM")
    t = sim.speedup_table(get_config("bert-l"), cm.edge_env("A"), cm.mbps(125), 284)
    assert isinstance(t["sp"], float) and 1.0 < t["sp"] < 1.3


def test_fig9_heterogeneous_band():
    """Heterogeneous envs: paper reports 1.3x-2.5x overall latency reduction."""
    speedups = []
    for env in ("D", "E", "F"):
        t = sim.speedup_table(get_config("bert-l"), cm.edge_env(env), cm.mbps(125), 284)
        if isinstance(t["megatron"], float):
            speedups.append(t["megatron"])
    assert speedups and min(speedups) > 1.3 and max(speedups) < 2.9


def test_fig10_weak_scaling_efficiency():
    """Paper: 81% (GPT2-L) / 86% (OPT-XL) of linear at 4 devices, 1Gbps."""
    for model, paper in [("gpt2-l", 0.81), ("opt-xl", 0.86)]:
        eff = weak_scaling(get_config(model), cm.jetson_nano("nano-m", 1.5),
                           cm.mbps(1000), 96)[3]
        assert abs(eff - paper) < 0.12, (model, eff)


def test_fig11_strong_scaling():
    """Paper: 3.05x (GPT2-L) / 3.24x (OPT-XL) vs local at 4 devices."""
    for model, paper in [("gpt2-l", 3.05), ("opt-xl", 3.24)]:
        s = strong_scaling(get_config(model), cm.jetson_nano("nano-m", 1.5),
                           cm.mbps(1000), 384)[3]
        assert abs(s - paper) / paper < 0.20, (model, s)


def test_table5_gpu_band():
    """GPU env (2x nano GPU, 500Mbps): Galaxy > SP > 1 and Galaxy > M-LM."""
    for model, p_mlm, p_sp in [("opt-l", 1.58, 1.26), ("opt-xl", 1.47, 1.19)]:
        t = sim.speedup_table(get_config(model), [cm.jetson_nano_gpu(6.0)] * 2,
                              cm.mbps(500), 284)
        assert abs(t["megatron"] - p_mlm) < 0.35
        assert abs(t["sp"] - p_sp) < 0.25


def test_overlap_always_helps():
    """galaxy_overlap <= galaxy (sync) across bandwidths (Fig. 8 trend)."""
    cfg = get_config("bert-l")
    for mb in (62.5, 125, 250, 500, 1000):
        g = sim.simulate(cfg, cm.edge_env("B"), cm.mbps(mb), 284, "galaxy")
        o = sim.simulate(cfg, cm.edge_env("B"), cm.mbps(mb), 284, "galaxy_overlap")
        assert o.latency <= g.latency * 1.05
