"""End-to-end behaviour tests for the Galaxy reproduction as a system:
train -> checkpoint -> restore -> serve, plus the roofline toolchain and
the launch-layer input specs for all 40 (arch x shape) combinations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.shapes import SHAPES, input_specs, shape_config
from repro.models import init_params
from repro.roofline.analysis import collective_bytes, model_flops
from repro.serving import Request, ServingEngine
from repro.training import (
    AdamW, cosine_schedule, make_train_step, restore_checkpoint, save_checkpoint,
)
from repro.data import DataConfig, LMDataPipeline

from helpers import smoke_cfg


def test_train_checkpoint_serve_roundtrip(tmp_path):
    """The full product loop: train a model, checkpoint it, restore it,
    serve generation with it — outputs must match the pre-save engine."""
    cfg = smoke_cfg("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(cosine_schedule(1e-3, 2, 30))
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    pipe = iter(LMDataPipeline(cfg, DataConfig(batch_size=4, seq_len=32)))
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, state, _ = step(params, state, batch, jax.random.PRNGKey(i))

    ck = str(tmp_path / "ck")
    save_checkpoint(ck, 5, params, meta={"arch": cfg.name})
    _, restored, _ = restore_checkpoint(ck, params)

    def serve(p):
        eng = ServingEngine(p, cfg, max_batch=2, max_len=24)
        eng.submit(Request(uid=0, prompt=[5, 6, 7, 8], max_new_tokens=6))
        return eng.run()[0].output

    assert serve(params) == serve(restored)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_all_40_combos(arch, shape):
    """Every (arch x shape) pair has well-formed abstract inputs (the
    dry-run's contract): right global shapes, no allocation."""
    cfg = shape_config(get_config(arch), shape)
    specs = input_specs(cfg, shape, rules=None)
    info = SHAPES[shape]
    main = specs.get("tokens", specs.get("embeds"))
    if info["mode"] in ("train", "prefill"):
        assert main.shape[:2] == (info["batch"], info["seq"])
    else:
        assert main.shape[:2] == (info["batch"], 1)
        assert "cache" in specs and "cache_index" in specs
        # sub-quadratic requirement: long_500k caches must be bounded
        if shape == "long_500k":
            leaves = jax.tree.leaves(specs["cache"])
            biggest = max(int(np.prod(x.shape)) * x.dtype.itemsize for x in leaves)
            assert biggest < 2e9, "long-context cache must not be O(seq) full attention"
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_long500k_swaps_sliding_window():
    dense = get_config("qwen1.5-110b")
    assert dense.window == 0
    swapped = shape_config(dense, "long_500k")
    assert swapped.window == dense.long_context_window
    native = get_config("recurrentgemma-9b")
    assert shape_config(native, "long_500k").window == native.window  # unchanged


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[4,256]{1,0} all-gather(bf16[4,16]{1,0} %x), replica_groups={}
  %ar = (f32[128]{0}, f32[128]{0}) all-reduce(...), to_apply=%sum
  %cp = bf16[2,8]{1,0} collective-permute(bf16[2,8]{1,0} %y)
  %ags = bf16[64]{0} all-gather-start(bf16[4]{0} %z)
  %agd = bf16[64]{0} all-gather-done(bf16[64]{0} %ags)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 4 * 256 * 2 + 64 * 2  # -start counted, -done not
    assert out["all-reduce"] == 2 * 128 * 4
    assert out["collective-permute"] == 2 * 8 * 2
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_model_flops_conventions():
    cfg = get_config("qwen1.5-0.5b")
    train = model_flops(cfg, SHAPES["train_4k"], True)
    assert train == 6.0 * cfg.param_count(active_only=True) * 256 * 4096
    decode = model_flops(cfg, SHAPES["decode_32k"], False)
    assert decode == 2.0 * cfg.param_count(active_only=True) * 128
    moe = get_config("olmoe-1b-7b")
    assert model_flops(moe, SHAPES["train_4k"], True) < 6.0 * moe.param_count() * 256 * 4096
