"""RingSchedule API: construction, wire accounting, the schedule-only
primitive signatures, and the decode-attention valid-head gather.

These run on a single device: the ring primitives only need a named axis
(``jax.vmap(axis_name=...)``), and the schedule itself is pure host-side
geometry.  Multi-device execution of the transports is covered by
tests/test_execplan.py; hypothesis sweeps live in tests/test_ring_ragged.py.
"""
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import hmp, ring
from repro.core.execplan import ExecPlan
from repro.core.ring import RingSchedule, TileSpec

D_MODEL, F_LOC = 6, 5


# --- construction & geometry --------------------------------------------------

def test_ragged_buckets_round_to_grain():
    s = RingSchedule.ragged((2, 0, 3, 1), pad_tile=8, transport="bucketed")
    # default grain = ceil(8 / BUCKETS_PER_TILE) = 2
    assert tuple(s.buckets) == (2, 0, 4, 2)
    assert tuple(s.valid_sizes) == (2, 0, 3, 1)
    assert s.is_masked and s.is_bucketed
    assert s.segment_bounds == (0, 2, 4)
    # zero tiles ship nothing; wire accounting matches by hand
    assert s.total_wire_rows() == 3 * (2 + 0 + 4 + 2)
    assert s.padded_wire_rows() == 3 * 4 * 8
    assert s.wire_fraction() == pytest.approx(8 / 32)


def test_padded_transport_ships_full_tiles():
    s = RingSchedule.ragged((2, 0, 3, 1), pad_tile=8)
    assert tuple(s.buckets) == (8, 8, 8, 8)
    assert not s.is_bucketed
    assert s.wire_fraction() == 1.0


def test_dense_schedule():
    s = RingSchedule.dense(4, 8, double_buffer=True)
    assert tuple(s.valid_sizes) == (8, 8, 8, 8)
    assert not s.is_masked and not s.is_bucketed
    assert s.buffer_slot(0) == 0 and s.buffer_slot(3) == 1
    # source walks the ring backwards: at step r device i holds tile (i-r)%d
    assert [s.source(1, r) for r in range(4)] == [1, 0, 3, 2]


def test_schedule_validation():
    with pytest.raises(ValueError, match="transport"):
        RingSchedule.ragged((1, 2), transport="compressed")
    with pytest.raises(ValueError, match="pad_tile"):
        RingSchedule.ragged((5, 2), pad_tile=4)  # valid > pad
    with pytest.raises(ValueError, match="bucket"):
        RingSchedule((TileSpec(0, 2, 1),), pad_tile=4)  # valid > bucket
    with pytest.raises(ValueError, match="owner"):
        RingSchedule((TileSpec(1, 2, 2),), pad_tile=4)  # owner != position


# --- schedule-only signatures -------------------------------------------------

def _vmapped(fn, **kw):
    return jax.vmap(lambda a, b: fn(a, b, "ring", **kw), axis_name="ring")


def test_plain_dense_call_does_not_warn():
    x = jnp.ones((2, 1, 4, D_MODEL))
    w = jnp.ones((2, D_MODEL, F_LOC))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        _vmapped(ring.ring_allgather_matmul)(x, w)


def test_legacy_kwargs_removed():
    """The PR-6 shims are gone: the pre-schedule keywords now fail like any
    unknown keyword, and the removed hmp paged names no longer exist."""
    x = jnp.ones((2, 1, 4, D_MODEL))
    w = jnp.ones((2, D_MODEL, F_LOC))
    with pytest.raises(TypeError, match="tile_size"):
        _vmapped(ring.ring_allgather_matmul, tile_size=4)(x, w)
    with pytest.raises(TypeError, match="valid_sizes"):
        _vmapped(ring.sync_allgather_matmul, valid_sizes=(4, 4))(x, w)
    assert not hasattr(hmp, "hmp_prefill_paged")
    assert not hasattr(hmp, "hmp_decode_paged")


# --- decode attention: valid-head page gather ---------------------------------

def test_paged_kv_gather_reads_only_valid_head_slots():
    """The uneven-heads decode gather routes pad head slots to the null
    page: valid head slots must match the full gather bitwise even when the
    *other* pages' pad slots hold garbage, and pad head slots must read
    page 0 (zeros in a real pool) instead of arbitrary pages."""
    rng = np.random.default_rng(0)
    pages, page, h, hd, s, w = 6, 4, 5, 3, 2, 2
    pool = jnp.asarray(rng.normal(size=(pages, page, h, hd)))  # garbage all over
    block_table = jnp.asarray(rng.integers(1, pages, size=(s, w)), jnp.int32)
    head_ok = jnp.asarray([True, True, True, False, False])

    got = hmp._paged_kv_gather(pool, block_table, head_ok)
    full = np.asarray(pool)[np.asarray(block_table)].reshape(s, w * page, h, hd)
    assert got.shape == full.shape
    assert np.array_equal(np.asarray(got)[:, :, :3], full[:, :, :3])
    # pad slots come from the null page, laid out page-major like `full`
    null = np.asarray(pool)[np.zeros((s, w), int)].reshape(s, w * page, h, hd)
    assert np.array_equal(np.asarray(got)[:, :, 3:], null[:, :, 3:])
    # and with an all-valid mask the gather IS the full gather
    all_ok = jnp.ones((h,), bool)
    assert np.array_equal(np.asarray(hmp._paged_kv_gather(pool, block_table,
                                                          all_ok)), full)


# --- ExecPlan threading -------------------------------------------------------

def test_execplan_transport_knobs():
    ep = ExecPlan(heads=(6, 4, 4, 2), columns=(24, 16, 16, 8), head_dim=2,
                  d_model=32, seq_shares=(1.0, 2.0, 2.0, 5.0))
    with pytest.raises(ValueError, match="transport"):
        ep.with_transport("compressed")
    db = ep.with_transport("bucketed", double_buffer=True)
    assert (db.transport, db.double_buffer) == ("bucketed", True)
    assert (ep.transport, ep.double_buffer) == ("padded", False)  # unchanged
    assert "transport=bucketed+db" in db.describe()
    assert "wire=" in db.describe()
    assert "transport=padded" in ep.describe()

    # padded transport ships the straggler's fraction on every hop;
    # bucketed rounds each share up to the top/BUCKETS_PER_TILE grain
    top = 0.5
    assert np.allclose(ep.wire_fractions(), top)
    wf = db.wire_fractions()
    assert np.all(wf <= top + 1e-12)
    assert np.all(wf >= ep.seq_fractions - 1e-12)
    assert wf[0] == pytest.approx(top / ring.BUCKETS_PER_TILE)

    # the simulator's view: seq_wire set only for bucketed transport
    assert ep.to_planner_plan(padded=True).seq_wire is None
    wire = db.to_planner_plan(padded=True).seq_wire
    assert wire is not None and np.allclose(wire, wf)


def test_execplan_ring_schedule_matches_layout():
    ep = ExecPlan(heads=(4, 4, 4, 4), columns=(16, 16, 16, 16), head_dim=2,
                  d_model=32, seq_shares=(1.0, 2.0, 2.0, 5.0),
                  transport="bucketed", double_buffer=True)
    seq = 20
    sched = ep.ring_schedule(seq)
    assert tuple(sched.valid_sizes) == ep.seq_tiles(seq)
    assert sched.pad_tile == ep.seq_tile(seq)
    assert sched.transport == "bucketed" and sched.double_buffer
    gemm = lambda t, s: t
    assert ep.ring_schedule(seq, gemm=gemm).gemm is gemm
