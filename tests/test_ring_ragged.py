"""Ragged ring schedule: property tests against the sync references.

The ring primitives only need a named axis, not a physical mesh: ``jax.vmap
(axis_name=...)`` implements ``ppermute`` / ``axis_index`` / ``psum_scatter``
over the mapped axis on a single device, so hypothesis can sweep random tile
splits (including zero-sized tiles) cheaply in-process.  The shard_map path
over real forced devices is covered by tests/test_execplan.py.

Every sweep runs all three transport variants — padded, bucketed, and
bucketed with double-buffered tile overlap — and asserts the bucketed
variants are *bitwise* equal to the padded ring (same summation order, pad
rows zero either way), which in turn matches the sync reference.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import example, given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import ring  # noqa: E402
from repro.core.ring import RingSchedule  # noqa: E402
from repro.core.execplan import SeqLayout  # noqa: E402

D_MODEL, F_LOC, BATCH = 6, 5, 2

tiles_strategy = st.lists(st.integers(0, 5), min_size=2, max_size=6).filter(
    lambda t: max(t) > 0
)

VARIANTS = (
    dict(transport="padded"),
    dict(transport="bucketed"),
    dict(transport="bucketed", double_buffer=True),
    dict(transport="padded", double_buffer=True),
)


def _schedule(layout, **kw):
    return RingSchedule.ragged(layout.tiles, pad_tile=layout.pad_tile, **kw)


def _ring_over(fn, sched):
    return jax.vmap(
        lambda a, w: fn(a, w, "ring", schedule=sched),
        axis_name="ring",
    )


@settings(max_examples=30, deadline=None)
@given(tiles=tiles_strategy, seed=st.integers(0, 2**16))
@example(tiles=[2, 0, 3, 1], seed=0)   # zero-sized tile
@example(tiles=[0, 5, 0], seed=1)      # only one device holds rows
@example(tiles=[4, 4], seed=2)         # dense (masking must be a no-op)
def test_ragged_allgather_matmul_matches_sync(tiles, seed):
    layout = SeqLayout(tuple(tiles))
    n, t, p = layout.num_devices, layout.pad_tile, layout.padded_len
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (BATCH, layout.seq, D_MODEL))
    w = jax.random.normal(k2, (n, D_MODEL, F_LOC))
    x_dev = jnp.asarray(layout.scatter(x)).reshape(
        BATCH, n, t, D_MODEL).transpose(1, 0, 2, 3)

    out_ring = _ring_over(ring.ring_allgather_matmul, _schedule(layout))(x_dev, w)
    out_sync = _ring_over(ring.sync_allgather_matmul, _schedule(layout))(x_dev, w)

    # reference: dense GEMM of the real rows, scattered to the padded
    # layout; pad rows must be exactly zero
    ref = jnp.einsum("bsd,ndf->nbsf", x, w)
    ref_pad = jnp.zeros((n, BATCH, p, F_LOC)).at[:, :, layout.rows].set(ref)
    np.testing.assert_allclose(np.asarray(out_ring), np.asarray(ref_pad),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(out_sync), np.asarray(ref_pad),
                               atol=1e-4)

    # bucketed / double-buffered transports keep the dataflow and summation
    # order, so the outputs must be bitwise-identical to the padded ring
    for kw in VARIANTS[1:]:
        out_v = _ring_over(ring.ring_allgather_matmul,
                           _schedule(layout, **kw))(x_dev, w)
        assert np.array_equal(np.asarray(out_v), np.asarray(out_ring)), kw


@settings(max_examples=30, deadline=None)
@given(tiles=tiles_strategy, seed=st.integers(0, 2**16))
@example(tiles=[2, 0, 3, 1], seed=0)
@example(tiles=[0, 5, 0], seed=1)
@example(tiles=[4, 4], seed=2)
def test_ragged_reducescatter_matches_sync(tiles, seed):
    layout = SeqLayout(tuple(tiles))
    n, t, p = layout.num_devices, layout.pad_tile, layout.padded_len
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    # per-device column-shard activations over the padded sequence; pad rows
    # deliberately carry garbage — the schedule must mask it out
    h = jax.random.normal(k1, (n, BATCH, p, F_LOC))
    w = jax.random.normal(k2, (n, F_LOC, D_MODEL))

    out_ring = _ring_over(ring.matmul_ring_reducescatter, _schedule(layout))(h, w)
    out_sync = _ring_over(ring.sync_matmul_reducescatter, _schedule(layout))(h, w)

    h_masked = jnp.where(jnp.asarray(layout.valid)[None, None, :, None], h, 0)
    full = jnp.einsum("nbsf,nfd->bsd", h_masked, w)
    ref = full.reshape(BATCH, n, t, D_MODEL).transpose(1, 0, 2, 3)
    np.testing.assert_allclose(np.asarray(out_ring), np.asarray(ref),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(out_sync), np.asarray(ref),
                               atol=1e-4)

    for kw in VARIANTS[1:]:
        out_v = _ring_over(ring.matmul_ring_reducescatter,
                           _schedule(layout, **kw))(h, w)
        assert np.array_equal(np.asarray(out_v), np.asarray(out_ring)), kw


def test_schedule_validation_at_call():
    """Trace-time geometry checks of the schedule-only signatures."""
    x = jnp.zeros((1, 4, D_MODEL))
    w = jnp.zeros((D_MODEL, F_LOC))
    with pytest.raises(ValueError, match="devices"):
        jax.vmap(
            lambda a, b: ring.ring_allgather_matmul(
                a, b, "ring",
                schedule=RingSchedule.ragged((1, 2, 3), pad_tile=4)),
            axis_name="ring",  # 3-device schedule on a 2-device ring
        )(jnp.stack([x, x]), jnp.stack([w, w]))
    with pytest.raises(ValueError, match="pad_tile"):
        jax.vmap(
            lambda a, b: ring.ring_allgather_matmul(
                a, b, "ring", schedule=RingSchedule.dense(2, 8)),
            axis_name="ring",  # pad_tile 8 vs local tile of 4
        )(jnp.stack([x, x]), jnp.stack([w, w]))
    with pytest.raises(ValueError, match="does not divide"):
        # no schedule and a non-dividing sequence: the dense default cannot
        # cover it — callers must bring a ragged layout
        jax.vmap(
            lambda a, b: ring.matmul_ring_reducescatter(
                a, b, "ring"),
            axis_name="ring",
        )(jnp.zeros((2, 1, 5, F_LOC)), jnp.zeros((2, F_LOC, D_MODEL)))
