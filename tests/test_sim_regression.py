"""Simulated-latency regression gate.

Scores canonical ExecPlans with ``simulate_execplan`` against checked-in
golden latencies (``tests/golden/sim_latency.json``), so cost-model or
planner changes that blow up simulated latency fail tier-1 instead of
slipping through as a silent perf regression.  The tolerance is wide
(±20%): the gate catches blown-up plans and broken cost constants, not
calibration tweaks.  After an *intentional* cost-model change, regenerate
with::

    PYTHONPATH=src python tests/test_sim_regression.py --regen
"""
import dataclasses
import json
import os

import pytest

from repro.configs import get_config
from repro.core import costmodel, planner
from repro.core.execplan import ExecPlan
from repro.core.profiler import AnalyticProfiler
from repro.core.simulator import simulate_execplan, spec_decode_summary

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "sim_latency.json")
TOLERANCE = 0.20


def _cluster(caps, mem=1.5e9):
    return [
        costmodel.DeviceSpec(f"edge{i}", flops=c * 7.1e9, mem_bw=4.0e9,
                             memory_budget=mem)
        for i, c in enumerate(caps)
    ]


def _planned(cfg, devices, seq):
    prof = AnalyticProfiler(cfg, seq)
    pl = planner.plan(prof.model_profile(), prof.device_profiles(devices))
    assert pl.feasible, pl.reason
    return ExecPlan.from_plan(pl, head_dim=cfg.head_dim, d_model=cfg.d_model)


def _planned_ragged(cfg, devices, links, seq):
    prof = AnalyticProfiler(cfg, seq)
    pl = prof.plan(devices, links=links)
    assert pl.feasible, pl.reason
    ep = ExecPlan.from_plan(pl, head_dim=cfg.head_dim, d_model=cfg.d_model)
    assert ep.uneven_seq, ep.describe()
    return ep


def scenarios():
    """Canonical (name, eplan, cfg, devices, link, seq) rows.

    One uneven 4-device plan (the paper's heterogeneous testbed shape), an
    even 4-device split (planner degenerate case), an 8-device skewed
    cluster (the serving acceptance mesh), and a ragged-SP plan on a
    skewed-link cluster (bandwidth-aware uneven sequence tiles)."""
    cfg1 = dataclasses.replace(get_config("distilbert"), num_layers=1)
    link = costmodel.mbps(1000)
    out = []

    devs = _cluster([3.0, 2.0, 2.0, 1.0])
    out.append(("distilbert_4dev_3221", _planned(cfg1, devs, 128),
                cfg1, devs, link, 128))

    devs_even = _cluster([1.0, 1.0, 1.0, 1.0])
    ep_even = ExecPlan.even(4, num_heads=cfg1.num_heads, d_ff=cfg1.d_ff,
                            head_dim=cfg1.head_dim, d_model=cfg1.d_model)
    out.append(("distilbert_4dev_even", ep_even, cfg1, devs_even, link, 128))

    devs8 = _cluster([3.0, 2.0, 2.0, 1.0, 4.0, 1.0, 2.0, 3.0])
    out.append(("distilbert_8dev_skewed", _planned(cfg1, devs8, 256),
                cfg1, devs8, link, 256))

    # ragged SP: one 100 Mbps hop in an otherwise 1 Gbps ring
    skewed_links = [costmodel.mbps(1000), costmodel.mbps(1000),
                    costmodel.mbps(100), costmodel.mbps(1000)]
    out.append(("distilbert_4dev_raggedsp",
                _planned_ragged(cfg1, devs, skewed_links, 128),
                cfg1, devs, skewed_links, 128))
    return out


def _score(eplan, cfg, devices, link, seq):
    return {
        "sync_us": simulate_execplan(
            eplan, cfg, devices, link, seq, overlap=False).latency * 1e6,
        "overlap_us": simulate_execplan(
            eplan, cfg, devices, link, seq, overlap=True).latency * 1e6,
        "padded_us": simulate_execplan(
            eplan, cfg, devices, link, seq, overlap=True,
            padded=True).latency * 1e6,
        # SPMD execution with the pad-shedding pallas backend: compute at
        # effective units, transport still ships the padded sequence tile
        "padshed_us": simulate_execplan(
            eplan.with_backend("pallas"), cfg, devices, link, seq,
            overlap=True, padded=True).latency * 1e6,
        # SPMD execution with bucketed ragged transport + double-buffered
        # tile overlap: compute stays padded, but every ring hop ships only
        # its tile's bucketed rows (ExecPlan.wire_fractions)
        "bucketed_overlap_us": simulate_execplan(
            eplan.with_transport("bucketed", double_buffer=True), cfg,
            devices, link, seq, overlap=True, padded=True).latency * 1e6,
        # suffix-only prefill after a shared-prefix KV-cache hit covering
        # half the prompt: GEMMs/transport run over seq/2 rows, the
        # attention core reads the full seq keys from shared pages
        "prefix_hit_us": simulate_execplan(
            eplan, cfg, devices, link, seq, overlap=True,
            cached_prefix=seq // 2).latency * 1e6,
        # one speculative round (serving/spec.py) at the canonical operating
        # point: k=4 drafts on the fastest device + a 5-row verify chunk,
        # expressed as modeled time per emitted token at 80% acceptance
        "spec_decode_us": spec_decode_summary(
            eplan, cfg, devices, link, draft_cfg=cfg, k=4,
            acceptance=0.8, context_len=seq)["time_per_token_spec"] * 1e6,
    }


def _golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.mark.parametrize("name,eplan,cfg,devices,link,seq",
                         scenarios(), ids=lambda v: v if isinstance(v, str) else "")
def test_simulated_latency_within_golden(name, eplan, cfg, devices, link, seq):
    golden = _golden()
    assert name in golden, f"no golden entry for {name}; run --regen"
    got = _score(eplan, cfg, devices, link, seq)
    for key, want in golden[name].items():
        have = got[key]
        assert abs(have - want) <= TOLERANCE * want, (
            f"{name}/{key}: simulated {have:.1f}us vs golden {want:.1f}us "
            f"(>{TOLERANCE:.0%} drift) — if the cost-model change is "
            f"intentional, regenerate tests/golden/sim_latency.json"
        )


def test_golden_covers_all_scenarios():
    golden = _golden()
    assert set(golden) == {row[0] for row in scenarios()}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true")
    if ap.parse_args().regen:
        data = {
            name: _score(eplan, cfg, devices, link, seq)
            for name, eplan, cfg, devices, link, seq in scenarios()
        }
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN}")
        for name, row in data.items():
            print(f"  {name}: " + ", ".join(f"{k}={v:.1f}" for k, v in row.items()))
