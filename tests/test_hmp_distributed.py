"""Multi-device HMP equivalence tests.

These need >1 XLA device, so each test runs a SUBPROCESS with
--xla_force_host_platform_device_count set (the main pytest process must
keep seeing 1 device).  The subprocess asserts allclose and exits nonzero
on failure.
"""
import os
import subprocess
import sys
import textwrap


REPO = os.path.join(os.path.dirname(__file__), "..")


def run_multidevice(body: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_all_schedules_match_reference():
    """hmp / hmp_ring / megatron / sp all reproduce the single-device layer
    (paper Fig. 5 consistency requirement)."""
    run_multidevice("""
        import jax, jax.numpy as jnp
        from repro.core import hmp
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4,), ('model',))
        p = hmp.init_layer_params(jax.random.PRNGKey(0), 64, 8, 128)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64)) * 0.5
        ref = hmp.reference_layer(p, x)
        for name, fn in hmp.SCHEDULES.items():
            out = fn(p, x, mesh)
            err = float(jnp.abs(out - ref).max())
            assert err < 1e-5, (name, err)
            print(name, 'ok', err)
    """)


def test_ring_primitives_match_sync():
    """ring AllGather⊗GEMM and GEMM⊗ReduceScatter == unoverlapped versions
    (paper §III-D: 'without yielding results inconsistent')."""
    run_multidevice("""
        import functools, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core import ring
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4,), ('model',))
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 16))
        w1 = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
        h = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 64))
        w2 = jax.random.normal(jax.random.PRNGKey(3), (64, 16))

        def ag(fn):
            return shard_map(lambda xl, wl: fn(xl, wl, 'model'), mesh=mesh,
                             in_specs=(P(None,'model',None), P(None,'model')),
                             out_specs=P(None,None,'model'))
        out_r = ag(ring.ring_allgather_matmul)(x, w1)
        out_s = ag(ring.sync_allgather_matmul)(x, w1)
        assert float(jnp.abs(out_r - out_s).max()) < 1e-5
        expected = jnp.einsum('bsd,df->bsf', x, w1)
        assert float(jnp.abs(out_r - expected).max()) < 1e-5

        def rs(fn):
            return shard_map(lambda hl, wl: fn(hl, wl, 'model'), mesh=mesh,
                             in_specs=(P(None,None,'model'), P('model',None)),
                             out_specs=P(None,'model',None))
        out_r = rs(ring.matmul_ring_reducescatter)(h, w2)
        out_s = rs(ring.sync_matmul_reducescatter)(h, w2)
        assert float(jnp.abs(out_r - out_s).max()) < 1e-5
        expected = jnp.einsum('bsf,fd->bsd', h, w2)
        assert float(jnp.abs(out_r - expected).max()) < 1e-4
        print('ring primitives ok')
    """)


def test_gspmd_model_matches_single_device():
    """The production GSPMD path (sharding constraints) is numerically the
    single-device model: run the reduced qwen forward on a 1x4 mesh."""
    run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.models import apply_model, init_params
        from repro.models.sharding import axis_rules, make_rules
        cfg = reduced(get_config('qwen1.5-0.5b'))
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
        ref, _, _ = apply_model(params, cfg, mode='train', tokens=toks)
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((1, 4), ('data', 'model'))
        rules = make_rules(mesh, 'train', batch_size=2)
        with mesh:
            def fwd(p, t):
                with axis_rules(rules):
                    return apply_model(p, cfg, mode='train', tokens=t)[0]
            out = jax.jit(fwd)(params, toks)
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-4, err
        print('gspmd ok', err)
    """)


def test_gspmd_moe_matches_single_device():
    run_multidevice("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config, reduced
        from repro.models import apply_model, init_params
        from repro.models.sharding import axis_rules, make_rules
        cfg = reduced(get_config('olmoe-1b-7b'))
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
        ref, _, _ = apply_model(params, cfg, mode='train', tokens=toks)
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 2), ('data', 'model'))
        rules = make_rules(mesh, 'train', batch_size=2)
        with mesh:
            def fwd(p, t):
                with axis_rules(rules):
                    return apply_model(p, cfg, mode='train', tokens=t)[0]
            out = jax.jit(fwd)(params, toks)
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-4, err
        print('gspmd moe ok', err)
    """)


def test_hmp_stack_of_layers():
    """Multiple stacked HMP layers (ring mode) remain consistent — catches
    cross-layer sharding drift."""
    run_multidevice("""
        import jax, jax.numpy as jnp
        from repro.core import hmp
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4,), ('model',))
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        layers = [hmp.init_layer_params(k, 32, 4, 64) for k in keys]
        x = jax.random.normal(jax.random.PRNGKey(9), (2, 8, 32)) * 0.5
        ref = x
        for p in layers:
            ref = hmp.reference_layer(p, ref)
        out = x
        for p in layers:
            out = hmp.hmp_layer(p, out, mesh, overlap=True)
        err = float(jnp.abs(out - ref).max())
        assert err < 2e-5, err
        print('stack ok', err)
    """)
