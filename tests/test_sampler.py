"""Samplers: greedy/temperature/top-k, and the per-position batch variant
used by speculative verification (temperature=0 must reduce to argmax)."""
import jax
import jax.numpy as jnp
import pytest

from repro.serving import SamplerConfig, sample, sample_positions


def test_samplers():
    logits = jnp.asarray([[0.0, 5.0, 1.0]])
    assert int(sample(logits, jax.random.PRNGKey(0), SamplerConfig())[0]) == 1
    t = sample(logits, jax.random.PRNGKey(0),
               SamplerConfig(temperature=1.0, top_k=2))
    assert int(t[0]) in (1, 2)


def test_sample_positions_greedy_is_argmax():
    """Property: at temperature=0, sample_positions == argmax over the vocab
    axis for every (batch, position), across random logits blocks."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 3),
           st.integers(1, 5), st.integers(2, 17))
    def prop(seed, b, k, v):
        logits = jax.random.normal(jax.random.PRNGKey(seed), (b, k, v))
        out = sample_positions(logits, jax.random.PRNGKey(0), SamplerConfig())
        assert out.shape == (b, k) and out.dtype == jnp.int32
        assert (out == jnp.argmax(logits, axis=-1)).all()

    prop()


def test_sample_positions_greedy_matches_columnwise_sample():
    logits = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 9))
    cfg = SamplerConfig()
    out = sample_positions(logits, jax.random.PRNGKey(0), cfg)
    for j in range(4):
        col = sample(logits[:, j], jax.random.PRNGKey(0), cfg)
        assert (out[:, j] == col).all()


def test_sample_positions_stochastic_valid_and_topk():
    logits = jax.random.normal(jax.random.PRNGKey(7), (3, 5, 11)) * 4.0
    cfg = SamplerConfig(temperature=0.7, top_k=2)
    out = sample_positions(logits, jax.random.PRNGKey(1), cfg)
    assert out.shape == (3, 5) and out.dtype == jnp.int32
    # each token must come from that position's top-2 logits
    top2 = jnp.argsort(logits, axis=-1)[..., -2:]
    hit = (out[..., None] == top2).any(-1)
    assert bool(hit.all())
    # split RNG per position: positions with identical logits still draw
    # independently, so two different keys disagree somewhere
    alt = sample_positions(logits, jax.random.PRNGKey(2), cfg)
    assert not bool((out == alt).all())
