"""Algorithm 1 (heterogeneity + memory aware planning): unit + property
tests (hypothesis) on the planner's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.planner import (
    DeviceProfile,
    ModelProfile,
    balanced_partition,
    memory_aware_balancing,
    plan,
    regularize_pad_spread,
)

BERT_L = ModelProfile("bert-l", num_layers=24, num_heads=16, mlp_columns=4096,
                      m_att=8.4e6, m_mlp=16.8e6)


def _devices(caps, budgets):
    return [DeviceProfile(f"d{i}", c, b) for i, (c, b) in enumerate(zip(caps, budgets))]


def test_balanced_partition_proportional():
    out = balanced_partition(16, [2.0, 1.0, 1.0])
    assert out.sum() == 16
    assert out[0] == 8 and out[1] == 4 and out[2] == 4


def test_balanced_partition_rounding_preserves_total():
    out = balanced_partition(16, [1.0, 1.0, 1.0])
    assert out.sum() == 16
    assert out.max() - out.min() <= 1


def test_plan_homogeneous_equal_split():
    devs = _devices([1.0] * 4, [1e9] * 4)
    p = plan(BERT_L, devs)
    assert p.feasible
    assert np.all(p.mha == 4)
    assert np.all(p.mlp == 1024)
    assert np.allclose(p.seq, 0.25)  # SP equal split (paper §III-C-2)


def test_plan_heterogeneous_proportional():
    devs = _devices([3.0, 1.0], [1e9, 1e9])
    p = plan(BERT_L, devs)
    assert p.feasible
    assert p.mha[0] == 12 and p.mha[1] == 4
    assert p.mlp[0] == 3072 and p.mlp[1] == 1024


def test_memory_rebalancing_shifts_from_oom_device():
    # device 1 has tiny memory: its share must shift to device 0
    total_mem = BERT_L.num_layers * (BERT_L.m_att + BERT_L.m_mlp)  # ~0.6 GB
    devs = _devices([1.0, 1.0], [0.9 * total_mem, 0.2 * total_mem])
    p = plan(BERT_L, devs)
    assert p.feasible, p.reason
    mem = p.memory_per_device(BERT_L)
    assert mem[0] <= devs[0].memory_budget
    assert mem[1] <= devs[1].memory_budget
    # Alg. 1 shifts MLP columns first (finer granularity, line 21): the
    # memory-starved device ends with strictly fewer columns
    assert p.mlp[0] > p.mlp[1]


def test_plan_fails_when_cluster_too_small():
    devs = _devices([1.0, 1.0], [1e6, 1e6])  # 1 MB budgets
    p = plan(BERT_L, devs)
    assert not p.feasible


def test_memory_aware_balancing_noop_when_feasible():
    units = np.array([8, 8])
    out = memory_aware_balancing(
        units, unit_mem=1.0, capacities=[1, 1], budgets=[100, 100],
        other_mem=np.zeros(2),
    )
    assert np.array_equal(out, units)


@settings(max_examples=200, deadline=None)
@given(
    caps=st.lists(st.floats(0.1, 10.0), min_size=2, max_size=6),
    total=st.integers(2, 128),
)
def test_property_balanced_partition_sums(caps, total):
    out = balanced_partition(total, caps)
    assert out.sum() == total
    assert (out >= 0).all()
    # monotone: a strictly faster device never gets strictly less
    for i in range(len(caps)):
        for j in range(len(caps)):
            if caps[i] > caps[j]:
                assert out[i] >= out[j] - 1  # rounding slack of 1 unit


def test_regularize_pad_spread_tradeoff():
    """pad_penalty co-optimizes balance vs max(units) spread: zero penalty
    is a no-op, a huge penalty converges to the equal split, and a moderate
    one lands between — always preserving the unit total."""
    caps = [3.0, 2.0, 2.0, 1.0]
    units = balanced_partition(16, caps)
    assert units.tolist() == [6, 4, 4, 2]

    assert regularize_pad_spread(units, caps, 0.0).tolist() == [6, 4, 4, 2]
    heavy = regularize_pad_spread(units, caps, 100.0)
    assert heavy.sum() == 16 and heavy.max() == 4  # equal split: no padding
    mild = regularize_pad_spread(units, caps, 0.5)
    assert mild.sum() == 16 and 4 <= mild.max() <= 6

    # through plan(): the padded straggler share shrinks monotonically
    model = ModelProfile("tiny", 2, 16, 64, 1e6, 2e6)
    devs = _devices(caps, [1e12] * 4)
    p0 = plan(model, devs)
    p1 = plan(model, devs, pad_penalty=100.0)
    assert p1.feasible
    assert p1.mha.max() <= p0.mha.max()
    assert p1.mlp.max() <= p0.mlp.max()
    assert p1.mha.sum() == 16 and p1.mlp.sum() == 64


@settings(max_examples=100, deadline=None)
@given(
    n=st.integers(2, 5),
    seed=st.integers(0, 10_000),
    tightness=st.floats(0.3, 3.0),
)
def test_property_plan_respects_budgets_or_fails(n, seed, tightness):
    rng = np.random.default_rng(seed)
    caps = rng.uniform(0.2, 5.0, n)
    total_mem = BERT_L.num_layers * (BERT_L.m_att + BERT_L.m_mlp)
    budgets = rng.uniform(0.1, 1.0, n) * total_mem * tightness
    p = plan(BERT_L, _devices(caps, budgets))
    if p.feasible:
        mem = p.memory_per_device(BERT_L)
        assert np.all(mem <= budgets + 1e-6)
        assert p.mha.sum() == BERT_L.num_heads
        assert p.mlp.sum() == BERT_L.mlp_columns
    else:
        # infeasible implies the sum of budgets is (close to) insufficient
        # or granularity prevented packing; either way no plan leaks OOM
        assert True
