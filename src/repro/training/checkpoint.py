"""Checkpointing: params + optimizer state to .npz with a JSON manifest.

Flattens the pytree with '/'-joined key paths; restores device-put against
the provided shardings (or host arrays when none).  No orbax in this
environment — this is a complete, self-contained implementation.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        if hasattr(leaf, "sharding") and leaf.sharding is not None and not isinstance(
            leaf, np.ndarray
        ):
            arr = jax.device_put(arr.astype(leaf.dtype), leaf.sharding)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(path: str, step: int, params, opt_state=None, meta: Optional[Dict[str, Any]] = None):
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, "opt_state.npz"), **_flatten(opt_state))
    manifest = {"step": int(step), **(meta or {})}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def restore_checkpoint(path: str, params_template, opt_template=None):
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "params.npz")) as z:
        params = _unflatten_into(params_template, dict(z))
    opt_state = None
    if opt_template is not None and os.path.exists(os.path.join(path, "opt_state.npz")):
        with np.load(os.path.join(path, "opt_state.npz")) as z:
            opt_state = _unflatten_into(opt_template, dict(z))
    return manifest, params, opt_state
