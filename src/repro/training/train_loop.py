"""Training step: loss (vocab-sharded cross-entropy), grads, AdamW update.

The loss keeps the vocab dimension model-sharded end-to-end: the one-hot
label contraction and the logsumexp both reduce over the sharded axis, so
GSPMD emits partial sums + a small AllReduce instead of gathering
(B, S, 152k) logits anywhere.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding import Rules, axis_rules, constrain
from repro.models.transformer import apply_model
from repro.training.optimizer import AdamW


@jax.custom_vjp
def _nll(logits, labels):
    """Per-token negative log-likelihood. logits: (..., V) model-dtype,
    labels: (...) int32 (callers clamp padding to 0 and mask outside).
    Custom VJP keeps exactly ONE (..., V) buffer in each direction (the
    bf16 shifted-exp / softmax); the naive autodiff path materializes
    several fp32 (B,S,150k) temps — the dominant HBM term at 4k batch."""
    loss, _ = _nll_fwd(logits, labels)
    return loss


def _nll_fwd(logits, labels):
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])  # model dtype (bf16): the one buffer
    sumexp = jnp.sum(p, axis=-1, dtype=jnp.float32)
    correct = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    lse = m.astype(jnp.float32) + jnp.log(sumexp)
    loss = lse - correct.astype(jnp.float32)
    return loss, (logits, labels, m, sumexp)


def _nll_bwd(res, g):
    logits, labels, m, sumexp = res
    dt = logits.dtype
    # softmax in model dtype: the single (..., V) backward buffer
    p = jnp.exp(logits - m[..., None]) / sumexp[..., None].astype(dt)
    grad = p * g[..., None].astype(dt)
    # subtract g at the label position (scatter, no one-hot buffer)
    idx = labels[..., None]
    upd = jnp.take_along_axis(grad, idx, axis=-1) - g[..., None].astype(dt)
    grad = jnp.put_along_axis(grad, idx, upd, axis=-1, inplace=False)
    return grad, None


_nll.defvjp(_nll_fwd, _nll_bwd)


def cross_entropy(logits, labels, cfg: ModelConfig):
    """logits: (B,S,V) or (B,S,cb,V) model-dtype; labels: (B,S) or (B,S,cb)
    int32, -1 = padding."""
    if logits.ndim == 3:
        logits = logits[:, :, None, :]
        labels = labels[:, :, None]
    nll = _nll(logits, jnp.maximum(labels, 0))
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss


def loss_fn(params, batch: Dict, cfg: ModelConfig, rng, unroll: bool = False
            ) -> Tuple[jax.Array, Dict]:
    kwargs = {}
    if cfg.input_mode == "token":
        kwargs["tokens"] = batch["tokens"]
    else:
        kwargs["embeds"] = batch["embeds"]
    if cfg.num_image_tokens:
        kwargs["img_embeds"] = batch["img_embeds"]
    logits, _, aux = apply_model(
        params, cfg, mode="train", rng=rng,
        deterministic=cfg.dropout_rate == 0.0, unroll=unroll, **kwargs,
    )
    ce = cross_entropy(logits, batch["labels"], cfg)
    loss = ce
    if cfg.is_moe:
        loss = loss + cfg.load_balance_loss_weight * aux["moe_lb_loss"] + 1e-3 * aux["moe_z_loss"]
    metrics = {"ce_loss": ce, **aux}
    return loss, metrics


def make_train_step(cfg: ModelConfig, opt: AdamW, rules: Optional[Rules] = None):
    """Returns train_step(params, opt_state, batch, rng) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch, rng):
        with axis_rules(rules):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, cfg, rng
            )
            params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, **metrics, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, rules: Optional[Rules] = None):
    def eval_step(params, batch):
        with axis_rules(rules):
            loss, metrics = loss_fn(params, batch, cfg, rng=None)
        return {"loss": loss, **metrics}

    return eval_step
