"""AdamW + schedules, pure JAX (no optax in this environment).

Optimizer state is a pytree congruent with the params; moments are fp32
regardless of param dtype (mixed-precision training discipline).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros, zeros)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        # global-norm clip
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
        lr = self.learning_rate(step)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32) * scale
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * jnp.square(g)
            mhat = mu / c1
            nhat = nu / c2
            delta = mhat / (jnp.sqrt(nhat) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, AdamWState(step, new_mu, new_nu), metrics


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return fn
