from repro.training.checkpoint import restore_checkpoint, save_checkpoint
from repro.training.optimizer import AdamW, AdamWState, cosine_schedule
from repro.training.train_loop import make_eval_step, make_train_step

__all__ = [
    "AdamW", "AdamWState", "cosine_schedule", "make_train_step",
    "make_eval_step", "save_checkpoint", "restore_checkpoint",
]
