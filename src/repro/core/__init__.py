"""Galaxy's primary contribution: hybrid model parallelism (hmp, ring),
heterogeneity+memory-aware planning (planner, profiler), and the calibrated
edge-cluster evaluation (costmodel, simulator)."""
from repro.core import costmodel, hmp, planner, profiler, ring, simulator  # noqa: F401
