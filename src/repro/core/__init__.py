"""Galaxy's primary contribution: hybrid model parallelism (hmp, ring),
heterogeneity+memory-aware planning (planner, profiler), the execution-plan
layer that materializes uneven plans (execplan), and the calibrated
edge-cluster evaluation (costmodel, simulator)."""
from repro.core import (  # noqa: F401
    costmodel,
    execplan,
    hmp,
    planner,
    profiler,
    ring,
    simulator,
)
from repro.core.execplan import ExecPlan  # noqa: F401
