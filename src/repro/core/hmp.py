"""Hybrid Model Parallelism (paper §III-B) as explicit shard_map programs.

This module is the *faithful* executable of the paper's Fig. 5 on a
Transformer layer (post-LN, as in Fig. 2): TP over heads (MHA) and FFN
columns (MLP), SP over the connective blocks, with a ReduceScatter exiting
each TP block and an AllGather entering it.  Three schedules:

* ``hmp``       — Galaxy HMP, synchronous collectives (faithful baseline)
* ``hmp_ring``  — Galaxy HMP + tile-based ring overlap (paper §III-D)
* ``megatron``  — Megatron-LM TP baseline: AllReduce after each block,
                  connective blocks computed redundantly on every device
* ``sp``        — pure Sequence Parallelism baseline: weights replicated,
                  2 AllGathers (K and V) per MHA block

All four produce identical math (up to summation order); tests assert
allclose against the single-device reference.  The production models use
the GSPMD expression of the same layout (models/sharding.py); this module
is the paper-exact schedule used for equivalence tests, benchmarks, and as
the template for the perf work.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.ring import (
    matmul_ring_reducescatter,
    ring_allgather_matmul,
    sync_allgather_matmul,
    sync_matmul_reducescatter,
)

AXIS = "model"


# --- paper-style layer (Fig. 2): post-LN MHA + MLP --------------------------

def init_layer_params(key, d_model: int, num_heads: int, d_ff: int, dtype=jnp.float32) -> Dict:
    hd = d_model // num_heads
    ks = jax.random.split(key, 6)
    s = 0.02
    return {
        "wq": jax.random.normal(ks[0], (d_model, num_heads, hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d_model, num_heads, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d_model, num_heads, hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (num_heads, hd, d_model), dtype) * s,
        "w1": jax.random.normal(ks[4], (d_model, d_ff), dtype) * s,
        "w2": jax.random.normal(ks[5], (d_ff, d_model), dtype) * s,
        "ln1_s": jnp.ones((d_model,), dtype),
        "ln1_b": jnp.zeros((d_model,), dtype),
        "ln2_s": jnp.ones((d_model,), dtype),
        "ln2_b": jnp.zeros((d_model,), dtype),
    }


def layer_param_specs(megatron: bool = False, sp: bool = False) -> Dict:
    """PartitionSpecs for the layer params under each parallelism plan."""
    if sp:  # weights replicated
        return {k: P() for k in (
            "wq", "wk", "wv", "wo", "w1", "w2", "ln1_s", "ln1_b", "ln2_s", "ln2_b")}
    return {
        "wq": P(None, AXIS, None),
        "wk": P(None, AXIS, None),
        "wv": P(None, AXIS, None),
        "wo": P(AXIS, None, None),
        "w1": P(None, AXIS),
        "w2": P(AXIS, None),
        "ln1_s": P(), "ln1_b": P(), "ln2_s": P(), "ln2_b": P(),
    }


def _ln(x, s, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * s + b).astype(x.dtype)


def _attention(q, k, v):
    """q,k,v: (B, S, H, hd) -> (B, S, H, hd), causal."""
    hd = q.shape[-1]
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) / np.sqrt(hd)
    s, t = scores.shape[-2], scores.shape[-1]
    mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def reference_layer(p: Dict, x):
    """Single-device oracle of the paper's Fig. 2 layer (post-LN)."""
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    attn = _attention(q, k, v)
    g = jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    x = _ln(x + g, p["ln1_s"], p["ln1_b"])
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"]))
    f = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    x = _ln(x + f, p["ln2_s"], p["ln2_b"])
    return x


# --- Galaxy HMP (shard_map) ---------------------------------------------------

def _hmp_layer_local(p, x_loc, *, overlap: bool):
    """Body on one device.  x_loc: (B, S_loc, d) sequence shard; params are
    head/column shards.  TP blocks see the full sequence; connective blocks
    see the local shard (paper Fig. 5)."""
    ag_mm = ring_allgather_matmul if overlap else sync_allgather_matmul
    mm_rs = matmul_ring_reducescatter if overlap else sync_matmul_reducescatter

    d_model = x_loc.shape[-1]
    h_loc, hd = p["wq"].shape[1], p["wq"].shape[2]

    # ---- MHA block (TP over heads) ----
    wqkv = jnp.concatenate(
        [p["wq"].reshape(d_model, -1), p["wk"].reshape(d_model, -1),
         p["wv"].reshape(d_model, -1)], axis=1)
    qkv = ag_mm(x_loc, wqkv, AXIS)  # AllGather ⊗ GEMM1  (B, S, 3*h_loc*hd)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    shape = (*q.shape[:2], h_loc, hd)
    attn = _attention(q.reshape(shape), k.reshape(shape), v.reshape(shape))
    attn = attn.reshape(*q.shape[:2], h_loc * hd)
    g_loc = mm_rs(attn, p["wo"].reshape(-1, d_model), AXIS)  # GEMM ⊗ ReduceScatter

    # ---- connective block (SP over local sequence shard) ----
    x_loc = _ln(x_loc + g_loc, p["ln1_s"], p["ln1_b"])

    # ---- MLP block (TP over columns) ----
    h = ag_mm(x_loc, p["w1"], AXIS)
    h = jax.nn.gelu(h)
    f_loc = mm_rs(h, p["w2"], AXIS)

    # ---- connective block ----
    x_loc = _ln(x_loc + f_loc, p["ln2_s"], p["ln2_b"])
    return x_loc


def hmp_layer(p: Dict, x, mesh: Mesh, *, overlap: bool = False):
    """Galaxy HMP layer. x: (B, S, d) global; S must divide the model axis."""
    fn = shard_map(
        functools.partial(_hmp_layer_local, overlap=overlap),
        mesh=mesh,
        in_specs=(layer_param_specs(), P(None, AXIS, None)),
        out_specs=P(None, AXIS, None),
    )
    return fn(p, x)


# --- Megatron-LM TP baseline -----------------------------------------------

def _megatron_layer_local(p, x):
    """x replicated; AllReduce after each block; connective computed
    redundantly on every device (the waste HMP eliminates)."""
    d_model = x.shape[-1]
    h_loc, hd = p["wq"].shape[1], p["wq"].shape[2]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    attn = _attention(q, k, v)
    g = jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    g = jax.lax.psum(g, AXIS)  # AllReduce #1
    x = _ln(x + g, p["ln1_s"], p["ln1_b"])  # redundant on all devices
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"]))
    f = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    f = jax.lax.psum(f, AXIS)  # AllReduce #2
    x = _ln(x + f, p["ln2_s"], p["ln2_b"])
    return x


def megatron_layer(p: Dict, x, mesh: Mesh):
    fn = shard_map(
        _megatron_layer_local,
        mesh=mesh,
        in_specs=(layer_param_specs(), P()),
        out_specs=P(),
    )
    return fn(p, x)


# --- pure Sequence Parallelism baseline ---------------------------------------

def _sp_layer_local(p, x_loc):
    """x seq-sharded; weights fully replicated (the memory wall).  K/V need
    the whole sequence: 2 AllGathers per MHA block (paper §IV-A)."""
    q = jnp.einsum("bsd,dhk->bshk", x_loc, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x_loc, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x_loc, p["wv"])
    k = jax.lax.all_gather(k, AXIS, axis=1, tiled=True)  # AllGather #1
    v = jax.lax.all_gather(v, AXIS, axis=1, tiled=True)  # AllGather #2
    # causal offset of the local query block
    idx = jax.lax.axis_index(AXIS)
    s_loc = q.shape[1]
    hd = q.shape[-1]
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) / np.sqrt(hd)
    q_pos = idx * s_loc + jnp.arange(s_loc)
    k_pos = jnp.arange(k.shape[1])
    mask = k_pos[None, :] <= q_pos[:, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    attn = jnp.einsum("bhst,bthd->bshd", probs, v)
    g = jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    x_loc = _ln(x_loc + g, p["ln1_s"], p["ln1_b"])
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x_loc, p["w1"]))
    f = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    x_loc = _ln(x_loc + f, p["ln2_s"], p["ln2_b"])
    return x_loc


def sp_layer(p: Dict, x, mesh: Mesh):
    fn = shard_map(
        _sp_layer_local,
        mesh=mesh,
        in_specs=(layer_param_specs(sp=True), P(None, AXIS, None)),
        out_specs=P(None, AXIS, None),
    )
    return fn(p, x)


SCHEDULES = {
    "hmp": lambda p, x, mesh: hmp_layer(p, x, mesh, overlap=False),
    "hmp_ring": lambda p, x, mesh: hmp_layer(p, x, mesh, overlap=True),
    "megatron": megatron_layer,
    "sp": sp_layer,
}
