"""Hybrid Model Parallelism (paper §III-B) as explicit shard_map programs.

This module is the *faithful* executable of the paper's Fig. 5 on a
Transformer layer (post-LN, as in Fig. 2): TP over heads (MHA) and FFN
columns (MLP), SP over the connective blocks, with a ReduceScatter exiting
each TP block and an AllGather entering it.  Three schedules:

* ``hmp``       — Galaxy HMP, synchronous collectives (faithful baseline)
* ``hmp_ring``  — Galaxy HMP + tile-based ring overlap (paper §III-D)
* ``megatron``  — Megatron-LM TP baseline: AllReduce after each block,
                  connective blocks computed redundantly on every device
* ``sp``        — pure Sequence Parallelism baseline: weights replicated,
                  2 AllGathers (K and V) per MHA block

All four produce identical math (up to summation order); tests assert
allclose against the single-device reference.

Heterogeneity-aware execution: every entry point takes an optional
``plan: ExecPlan`` (``core/execplan.py``).  The plan materializes the
planner's *uneven* head/column assignment as padded-and-masked shards —
each device's slice padded to ``max(units)`` with zeroed weights, so the
math stays exact while per-device shapes stay SPMD-equal.  The SP axis is
uneven the same way: a plan with ragged ``seq_shares`` runs the sequence
in a padded ragged layout (``execplan.SeqLayout``) — real rows scattered
to per-device offsets, pad rows masked out of the ring schedule and the
attention mask, and K/V written to the cache at *absolute* positions so
decode never sees the padding.  Callers pass the logical length as
``seq=`` and the sequence pre-scattered via ``layout.scatter``; with an
equal split of a dividing length the layout is dense and the code path is
bit-identical to the pre-ragged one.  Without a plan the layer behaves as
before (even split, padded == real).

Pluggable per-shard compute (``ExecPlan.compute_backend``): with the
default ``"xla"`` backend the padded shards run dense einsums — every
device executes ``max(units)`` work, zeros included (the honesty cost
``ExecPlan.padding_waste()`` bookkeeps; this path is the correctness
oracle).  With ``"pallas"`` every per-shard matmul and the prefill
attention route through ``kernels/ops.py``: per-device valid head/column
counts enter the valid-length kernels as scalar-prefetch operands and the
grids *skip* pad blocks, so executed MXU work tracks the plan's assigned
units.  The decode attention core stays XLA (it is a block-table gather,
not an MXU-bound GEMM); its projections shed like everything else.
Pallas inside shard_map needs ``check_rep=False`` (no replication rule for
``pallas_call``), so that flag flips only on the pallas path and the xla
graphs stay bit-identical to before.

Serving path: ``hmp_prefill`` / ``hmp_decode`` run a *stack* of layers
through the Galaxy schedule against a head-sharded KV cache — prefill is
the full TP/SP + ring program; decode is the single-token degenerate case
(pure TP with an AllReduce; an SP split of one token is meaningless), which
is what ``serving/galaxy.py`` drives from the wave scheduler.  One
keyword-normalized entry family covers every cache kind: ``seq=``,
``plan=``, the cache kind (dense, or paged via ``block_row=`` /
``block_table=``), and ``offset=`` compose orthogonally.
``hmp_prefill(..., block_row=)`` writes straight into pool pages
(continuous batching); adding ``offset=`` makes it the chunked/suffix-only
entry point — a chunk starting at an absolute offset attends back to the
KV pages already written by a shared prompt prefix
(``serving/prefix_cache.py``) and earlier chunks.  ``hmp_decode(...,
block_table=)`` is the paged slot-batch decode step.  (The pre-unification
``hmp_prefill_paged`` / ``hmp_decode_paged`` names were shimmed for one
release and have been removed.)

The ring side of every prefill runs a ``ring.RingSchedule`` built from the
plan (``ExecPlan.ring_schedule``): the plan's ``transport`` /
``double_buffer`` knobs select padded vs bucketed ragged transport and
explicit tile-level double buffering without touching this module's code
paths — the default padded single-buffer schedule keeps the exact
pre-schedule XLA graphs.

The production models use the GSPMD expression of the same layout
(models/sharding.py); this module is the paper-exact schedule used for
equivalence tests, benchmarks, and as the template for the perf work.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.execplan import ExecPlan, SeqLayout
from repro.core.ring import (
    RingSchedule,
    matmul_ring_reducescatter,
    ring_allgather_matmul,
    sync_allgather_matmul,
    sync_matmul_reducescatter,
)
from repro.kernels import ops

AXIS = "model"

# KV cache entries are (B, cache_len, heads, head_dim), head-sharded
CACHE_SPEC = P(None, None, AXIS, None)


# --- paper-style layer (Fig. 2): post-LN MHA + MLP --------------------------

def init_layer_params(key, d_model: int, num_heads: int, d_ff: int, dtype=jnp.float32) -> Dict:
    hd = d_model // num_heads
    ks = jax.random.split(key, 6)
    s = 0.02
    return {
        "wq": jax.random.normal(ks[0], (d_model, num_heads, hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d_model, num_heads, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d_model, num_heads, hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (num_heads, hd, d_model), dtype) * s,
        "w1": jax.random.normal(ks[4], (d_model, d_ff), dtype) * s,
        "w2": jax.random.normal(ks[5], (d_ff, d_model), dtype) * s,
        "ln1_s": jnp.ones((d_model,), dtype),
        "ln1_b": jnp.zeros((d_model,), dtype),
        "ln2_s": jnp.ones((d_model,), dtype),
        "ln2_b": jnp.zeros((d_model,), dtype),
    }


def init_stack_params(key, num_layers: int, d_model: int, num_heads: int,
                      d_ff: int, dtype=jnp.float32) -> List[Dict]:
    keys = jax.random.split(key, num_layers)
    return [init_layer_params(k, d_model, num_heads, d_ff, dtype) for k in keys]


def layer_param_specs(megatron: bool = False, sp: bool = False) -> Dict:
    """PartitionSpecs for the layer params under each parallelism plan.

    Identical for even and ExecPlan-padded layouts: padding only changes the
    (divisible) global extent of the sharded axes, not which axes shard.
    """
    if sp:  # weights replicated
        return {k: P() for k in (
            "wq", "wk", "wv", "wo", "w1", "w2", "ln1_s", "ln1_b", "ln2_s", "ln2_b")}
    return {
        "wq": P(None, AXIS, None),
        "wk": P(None, AXIS, None),
        "wv": P(None, AXIS, None),
        "wo": P(AXIS, None, None),
        "w1": P(None, AXIS),
        "w2": P(AXIS, None),
        "ln1_s": P(), "ln1_b": P(), "ln2_s": P(), "ln2_b": P(),
    }


def _ln(x, s, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * s + b).astype(x.dtype)


def _attention(q, k, v, mask=None):
    """q,k,v: (B, S, H, hd) -> (B, S, H, hd).  ``mask`` overrides the plain
    causal mask — a ragged ``SeqLayout`` supplies causality in the padded
    domain, where pad rows interleave with real positions."""
    hd = q.shape[-1]
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) / np.sqrt(hd)
    s, t = scores.shape[-2], scores.shape[-1]
    if mask is None:
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def reference_layer(p: Dict, x):
    """Single-device oracle of the paper's Fig. 2 layer (post-LN)."""
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    attn = _attention(q, k, v)
    g = jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    x = _ln(x + g, p["ln1_s"], p["ln1_b"])
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"]))
    f = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    x = _ln(x + f, p["ln2_s"], p["ln2_b"])
    return x


def reference_stack(layers: Sequence[Dict], x):
    for p in layers:
        x = reference_layer(p, x)
    return x


# --- Galaxy HMP (shard_map) ---------------------------------------------------

class _PallasCompute:
    """Per-device ragged compute bindings (``compute_backend="pallas"``).

    Built *inside* the shard_map body: ``axis_index`` resolves this
    device's valid head/column counts, which enter the valid-length
    kernels (``kernels/ops.py``) as scalar-prefetch operands — the kernel
    grids skip blocks that are entirely padding, so each device's executed
    MXU work tracks its assigned ``units[d]``, not ``max(units)``.  The
    methods double as the ring primitives' per-tile ``gemm`` callbacks
    (``valid_rows`` is the held tile's real row count in ring order).
    """

    def __init__(self, plan: ExecPlan, positions: Optional[np.ndarray]):
        idx = jax.lax.axis_index(AXIS)
        self.hd = plan.head_dim
        self.pad_heads = plan.pad_heads
        self.valid_heads = jnp.asarray(plan.heads, jnp.int32)[idx]
        self.valid_cols = jnp.asarray(plan.columns, jnp.int32)[idx]
        self.positions = positions  # padded row -> real position (static)

    def qkv_gemm(self, tile, w, valid_rows=None):
        # w = [wq | wk | wv]: three column segments, each a padded head
        # slot block with this device's real heads as the valid prefix
        return ops.gemm(tile, w, backend="pallas", valid_m=valid_rows,
                        valid_n=self.valid_heads * self.hd,
                        seg_n=self.pad_heads * self.hd)

    def wo_gemm(self, tile, w, valid_rows=None):
        return ops.gemm(tile, w, backend="pallas", valid_m=valid_rows,
                        valid_k=self.valid_heads * self.hd)

    def w1_gemm(self, tile, w, valid_rows=None):
        return ops.gemm(tile, w, backend="pallas", valid_m=valid_rows,
                        valid_n=self.valid_cols)

    def w2_gemm(self, tile, w, valid_rows=None):
        return ops.gemm(tile, w, backend="pallas", valid_m=valid_rows,
                        valid_k=self.valid_cols)

    def attention(self, q, k, v):
        """(B, S, H, hd) ragged flash attention: pad rows and pad head
        slots are skipped and come out exactly zero."""
        return ops.ragged_attention(q, k, v, positions=self.positions,
                                    valid_heads=self.valid_heads)

    def connective(self, x, res, scale, bias):
        """Fused residual + layernorm (one HBM pass) == ``_ln(res + x)``."""
        return ops.connective(x, res, scale, bias)


def _make_compute(backend: str, plan: Optional[ExecPlan],
                  layout: Optional[SeqLayout],
                  seq_total: Optional[int]) -> Optional[_PallasCompute]:
    if backend != "pallas":
        return None
    if layout is not None:
        positions = layout.positions
    elif seq_total is not None:
        positions = np.arange(seq_total)
    else:
        positions = None  # decode: attention stays on the XLA gather path
    return _PallasCompute(plan, positions)


def _ctx_attention(q, k, v, ctx, layout: Optional[SeqLayout]):
    """Chunked-prefill attention: chunk queries attend to already-written
    context pages plus the chunk's own K/V.

    ``ctx = (ctx_k, ctx_v, offset)``: ctx_k/ctx_v are (T, h_loc, hd)
    block-row gathers over *absolute* positions [0, T); only positions
    ``< offset`` (shared prefix pages + earlier chunks) are unmasked, so
    stale/null-page rows never contribute — they are exact zeros after the
    softmax, which keeps chunked outputs equal to the one-shot prefill.
    The local (chunk) part keeps the usual causal/ragged mask: relative
    causality inside a chunk is offset-invariant, and every context key
    precedes every real chunk query (ctx_pos < offset <= q_pos)."""
    ctx_k, ctx_v, offset = ctx
    s, t = q.shape[1], ctx_k.shape[0]
    if layout is not None:
        local = jnp.asarray(layout.attention_mask())
    else:
        local = jnp.tril(jnp.ones((s, s), bool))
    ctx_mask = jnp.broadcast_to(jnp.arange(t)[None, :] < offset, (s, t))
    mask = jnp.concatenate([ctx_mask, local], axis=1)
    kf = jnp.concatenate([ctx_k[None].astype(k.dtype), k], axis=1)
    vf = jnp.concatenate([ctx_v[None].astype(v.dtype), v], axis=1)
    return _attention(q, kf, vf, mask=mask)


def _hmp_layer_local(p, x_loc, *, overlap: bool, return_kv: bool = False,
                     layout: Optional[SeqLayout] = None,
                     plan: Optional[ExecPlan] = None, backend: str = "xla",
                     ctx=None):
    """Body on one device.  x_loc: (B, S_loc, d) sequence shard; params are
    head/column shards (possibly ExecPlan-padded with zero weights).  TP
    blocks see the full sequence; connective blocks see the local shard
    (paper Fig. 5).  With ``return_kv`` also emits this device's K/V head
    shards over the full sequence, for prefilling a decode cache.

    ``layout`` (a *ragged* SeqLayout; dense layouts pass None) drives the
    uneven-SP masking: the ring primitives zero pad rows per step, and the
    attention mask encodes causality over the padded row order.  Garbage in
    pad rows stays confined to pad rows — LN and residuals are rowwise, the
    rings zero their pad inputs, and attention masks pad keys — so every
    valid row is exact.

    ``ctx`` (chunked prefill; see ``_ctx_attention``) makes the attention
    additionally read already-written KV pages: the chunk's queries attend
    to context keys at absolute positions below the chunk offset.  The
    attention core then takes the XLA path even under the pallas backend
    (like decode it is a page-gather, not a self-attention the ragged flash
    kernel covers); the TP GEMMs still shed pad blocks."""
    ag_mm = ring_allgather_matmul if overlap else sync_allgather_matmul
    mm_rs = matmul_ring_reducescatter if overlap else sync_matmul_reducescatter

    d_model = x_loc.shape[-1]
    s_loc = x_loc.shape[1]
    h_loc, hd = p["wq"].shape[1], p["wq"].shape[2]
    n_dev = plan.num_devices if plan is not None else None
    # the ring program (tile geometry, wire format, overlap mode) is solved
    # ahead of trace time from the plan; without a plan the primitives build
    # their own dense even-split schedule from the shard shapes
    if plan is None:
        base_sched = None
    elif layout is not None:
        base_sched = plan.ring_schedule(layout=layout)
    else:
        base_sched = RingSchedule.dense(
            n_dev, s_loc, transport=plan.transport,
            double_buffer=plan.double_buffer)

    def _sched(gemm_fn):
        return None if base_sched is None else base_sched.with_gemm(gemm_fn)

    compute = _make_compute(backend, plan, layout,
                            None if n_dev is None else n_dev * s_loc)
    # the O(padded_len^2) ragged mask feeds only the xla attention path;
    # the pallas path derives masking from layout.positions in-kernel
    attn_mask = None if (layout is None or compute is not None) \
        else jnp.asarray(layout.attention_mask())

    # ---- MHA block (TP over heads) ----
    wqkv = jnp.concatenate(
        [p["wq"].reshape(d_model, -1), p["wk"].reshape(d_model, -1),
         p["wv"].reshape(d_model, -1)], axis=1)
    qkv = ag_mm(x_loc, wqkv, AXIS,
                schedule=_sched(compute.qkv_gemm if compute else None))  # AllGather ⊗ GEMM1
    q, k, v = jnp.split(qkv, 3, axis=-1)
    shape = (*q.shape[:2], h_loc, hd)
    k, v = k.reshape(shape), v.reshape(shape)
    if ctx is not None:
        attn = _ctx_attention(q.reshape(shape), k, v, ctx, layout)
    elif compute is not None:
        attn = compute.attention(q.reshape(shape), k, v)
    else:
        attn = _attention(q.reshape(shape), k, v, mask=attn_mask)
    attn = attn.reshape(*q.shape[:2], h_loc * hd)
    g_loc = mm_rs(attn, p["wo"].reshape(-1, d_model), AXIS,
                  schedule=_sched(compute.wo_gemm if compute else None))  # GEMM ⊗ ReduceScatter

    # ---- connective block (SP over local sequence shard) ----
    if compute is not None:
        y_loc = compute.connective(g_loc, x_loc, p["ln1_s"], p["ln1_b"])
    else:
        y_loc = _ln(x_loc + g_loc, p["ln1_s"], p["ln1_b"])

    # ---- MLP block (TP over columns) ----
    h = ag_mm(y_loc, p["w1"], AXIS,
              schedule=_sched(compute.w1_gemm if compute else None))
    h = jax.nn.gelu(h)
    f_loc = mm_rs(h, p["w2"], AXIS,
                  schedule=_sched(compute.w2_gemm if compute else None))

    # ---- connective block ----
    if compute is not None:
        out = compute.connective(f_loc, y_loc, p["ln2_s"], p["ln2_b"])
    else:
        out = _ln(y_loc + f_loc, p["ln2_s"], p["ln2_b"])
    if return_kv:
        return out, k, v
    return out


def _validate_plan(p: Dict, x, mesh: Mesh, plan: Optional[ExecPlan],
                   seq: Optional[int] = None):
    """Pad params and resolve the sequence layout for one entry point.

    Returns ``(params, layout)``; ``layout`` is None when there is no plan,
    no sequence, or the layout is dense (equal tiles fully covering the
    rows), so the dense path keeps its exact pre-ragged XLA graph."""
    n = mesh.shape[AXIS]
    layout = None
    if plan is not None:
        if plan.num_devices != n:
            raise ValueError(
                f"plan covers {plan.num_devices} devices but mesh axis "
                f"'{AXIS}' has {n}"
            )
        p = plan.ensure_padded(p)
        if x is not None:
            layout = plan.seq_layout(seq if seq is not None else x.shape[1])
            if x.shape[1] != layout.padded_len:
                raise ValueError(
                    f"sequence of {x.shape[1]} rows does not match the "
                    f"plan's padded ragged layout for seq={layout.seq} "
                    f"(tiles {list(layout.tiles)} pad to {layout.padded_len} "
                    f"rows); scatter it with plan.seq_layout(seq).scatter(x) "
                    f"and pass seq="
                )
            if layout.is_dense:
                layout = None
    return p, layout


def hmp_layer(p: Dict, x, mesh: Mesh, *, overlap: bool = False,
              plan: Optional[ExecPlan] = None, seq: Optional[int] = None):
    """Galaxy HMP layer.  x: (B, S, d) global.

    ``plan`` materializes an uneven planner assignment: reference-layout
    params are zero-padded per device (see ``ExecPlan.pad_layer_params``).
    A ragged SP plan (or a non-dividing length) additionally expects ``x``
    in the plan's padded ragged layout for the logical length ``seq``
    (``plan.seq_layout(seq).scatter(x)``); dense layouts take ``x`` as-is.
    """
    p, layout = _validate_plan(p, x, mesh, plan, seq=seq)
    backend = plan.compute_backend if plan is not None else "xla"
    fn = shard_map(
        functools.partial(_hmp_layer_local, overlap=overlap, layout=layout,
                          plan=plan, backend=backend),
        mesh=mesh,
        in_specs=(layer_param_specs(), P(None, AXIS, None)),
        out_specs=P(None, AXIS, None),
        check_rep=backend == "xla",  # pallas_call has no replication rule
    )
    return fn(p, x)


# --- multi-layer serving path: prefill + single-token decode ------------------

def make_kv_cache(batch: int, cache_len: int, num_layers: int, mesh: Mesh,
                  plan: ExecPlan, dtype=jnp.float32) -> List[Dict]:
    """Head-sharded KV cache for a stack of HMP layers.

    Each layer holds k/v of global shape (B, cache_len, padded_heads, hd);
    the head axis carries the plan's padded layout, so cache shards line up
    with the weight shards and padded head slots stay zero forever.  The
    sequence axis is unsharded — cache_len only needs to fit the (padded)
    prefill length plus decode steps.
    """
    shape = (batch, cache_len, plan.padded_heads, plan.head_dim)
    sharding = NamedSharding(mesh, CACHE_SPEC)
    return [
        {"k": jax.device_put(jnp.zeros(shape, dtype), sharding),
         "v": jax.device_put(jnp.zeros(shape, dtype), sharding)}
        for _ in range(num_layers)
    ]


def _prefill_layer_local(p, x_loc, ck, cv, *, overlap: bool,
                         layout: Optional[SeqLayout] = None,
                         plan: Optional[ExecPlan] = None,
                         backend: str = "xla"):
    y_loc, k, v = _hmp_layer_local(p, x_loc, overlap=overlap, return_kv=True,
                                   layout=layout, plan=plan, backend=backend)
    if layout is not None:
        # ragged layout: cache rows are *absolute* positions — gather the
        # valid rows out of the padded order before writing, so decode's
        # position-indexed reads line up
        k, v = k[:, layout.rows], v[:, layout.rows]
    ck = jax.lax.dynamic_update_slice(ck, k, (0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v, (0, 0, 0, 0))
    return y_loc, ck, cv


def hmp_prefill(layers: Sequence[Dict], x, mesh: Mesh, cache: List[Dict],
                *, plan: ExecPlan, overlap: bool = False,
                seq: Optional[int] = None, block_row=None, offset=None):
    """Run a stack of HMP layers over a prompt, filling the KV cache.

    One keyword-normalized prefill entry point; the orthogonal knobs are

    * ``seq=``     — logical prompt length under a ragged layout (``x`` is
      then ``plan.seq_layout(seq).scatter`` of the prompt); dense layouts
      pass ``x`` as-is.
    * cache kind   — ``cache`` is the dense per-layer k/v list from
      ``make_kv_cache`` by default; passing ``block_row=`` (this request's
      physical page ids, ``(pages_per_slot,)``) makes it the paged pool
      from ``make_paged_kv_cache`` and K/V scatter straight into pages
      (batch must be 1).
    * ``offset=``  — chunked / suffix-only prefill (paged only): ``x`` is
      one chunk starting at absolute position ``offset``; K/V land at
      [offset, offset + seq) and the chunk attends back to every
      already-written position below ``offset``.  A traced int32 scalar is
      fine — one compiled program per chunk shape.

    x: (B, S, d).  K/V land in the cache at absolute positions either way.
    Returns (y, cache) with y in the same layout as x.
    """
    if block_row is None:
        if offset is not None:
            raise ValueError(
                "offset= (chunked prefill) needs a paged cache; pass the "
                "request's block_row= as well"
            )
        return _prefill_dense(layers, x, mesh, cache, plan=plan,
                              overlap=overlap, seq=seq)
    return _prefill_paged(layers, x, mesh, cache, block_row, plan=plan,
                          overlap=overlap, seq=seq, offset=offset)


def _prefill_dense(layers: Sequence[Dict], x, mesh: Mesh, cache: List[Dict],
                   *, plan: ExecPlan, overlap: bool, seq: Optional[int]):
    validated = [_validate_plan(p, x, mesh, plan, seq=seq) for p in layers]
    layers = [p for p, _ in validated]
    layout = validated[0][1] if validated else None
    backend = plan.compute_backend
    fn = shard_map(
        functools.partial(_prefill_layer_local, overlap=overlap, layout=layout,
                          plan=plan, backend=backend),
        mesh=mesh,
        in_specs=(layer_param_specs(), P(None, AXIS, None), CACHE_SPEC, CACHE_SPEC),
        out_specs=(P(None, AXIS, None), CACHE_SPEC, CACHE_SPEC),
        check_rep=backend == "xla",
    )
    new_cache = []
    for p, c in zip(layers, cache):
        x, ck, cv = fn(p, x, c["k"], c["v"])
        new_cache.append({"k": ck, "v": cv})
    return x, new_cache


def _decode_mlp_tail(p, x, g, compute: Optional[_PallasCompute] = None):
    """Shared tail of the single-token TP step: attention output -> residual
    LN -> TP MLP (psum exit) -> residual LN.  ``compute`` routes the MLP
    GEMMs through the valid-length kernels (pad column blocks skipped)."""
    x = _ln(x + g, p["ln1_s"], p["ln1_b"])
    if compute is not None:
        h = jax.nn.gelu(compute.w1_gemm(x, p["w1"]))
        f = jax.lax.psum(compute.w2_gemm(h, p["w2"]), AXIS)
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"]))
        f = jax.lax.psum(jnp.einsum("bsf,fd->bsd", h, p["w2"]), AXIS)
    return _ln(x + f, p["ln2_s"], p["ln2_b"])


def _decode_qkv(p, x, compute: Optional[_PallasCompute]):
    """(B, S, d) -> q, k, v (B, S, h_loc, hd) through the backend (the
    fused-QKV projection shared by decode and the megatron baseline)."""
    h_loc, hd = p["wq"].shape[1], p["wq"].shape[2]
    if compute is None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        return q, k_new, v_new
    d_model = x.shape[-1]
    wqkv = jnp.concatenate(
        [p["wq"].reshape(d_model, -1), p["wk"].reshape(d_model, -1),
         p["wv"].reshape(d_model, -1)], axis=1)
    qkv = compute.qkv_gemm(x, wqkv)
    q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
    shape = (*x.shape[:2], h_loc, hd)
    return q.reshape(shape), k_new.reshape(shape), v_new.reshape(shape)


def _decode_layer_local(p, x, ck, cv, index, *,
                        plan: Optional[ExecPlan] = None,
                        backend: str = "xla"):
    """Single-token TP step on one device.  x: (B, 1, d) replicated; the SP
    axis is degenerate at one token, so connective blocks run redundantly and
    each TP block exits through an AllReduce (psum) instead of the ring.
    Writes this step's K/V into the local cache shard *before* attending, so
    position ``index`` is always valid.  index: (B,) per-slot positions —
    slots in a wave may sit at different depths (mixed-length prompts).

    With the pallas backend the projections shed pad head/column blocks;
    the attention core itself stays XLA (a cache gather + softmax, not an
    MXU-bound GEMM — pad head slots are zero in cache and query alike)."""
    d_model = x.shape[-1]
    b = x.shape[0]
    h_loc, hd = p["wq"].shape[1], p["wq"].shape[2]
    cache_len = ck.shape[1]
    compute = _make_compute(backend, plan, None, None)

    q, k_new, v_new = _decode_qkv(p, x, compute)
    rows = jnp.arange(b)
    ck = ck.at[rows, index].set(k_new[:, 0])
    cv = cv.at[rows, index].set(v_new[:, 0])

    scores = jnp.einsum("bqhd,bthd->bhqt", q, ck).astype(jnp.float32) / np.sqrt(hd)
    valid = jnp.arange(cache_len)[None, :] <= index[:, None]  # (B, T)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    attn = jnp.einsum("bhqt,bthd->bqhd", probs, cv).reshape(*x.shape[:2], h_loc * hd)
    if compute is not None:
        g = jax.lax.psum(compute.wo_gemm(attn, p["wo"].reshape(-1, d_model)), AXIS)
    else:
        g = jax.lax.psum(attn @ p["wo"].reshape(-1, d_model), AXIS)
    return _decode_mlp_tail(p, x, g, compute), ck, cv


def hmp_decode(layers: Sequence[Dict], x, mesh: Mesh, cache: List[Dict],
               index, *, plan: ExecPlan, block_table=None):
    """One decode step for a stack of HMP layers against the KV cache.

    The unified decode entry point: against the dense cache (default) x is
    a (B, 1, d) current-token embedding (replicated) and ``index`` a scalar
    int32 or (B,) vector of absolute positions (per-slot depths for
    mixed-length waves).  Passing ``block_table=`` ((S, W) int32 physical
    page per (slot, logical page)) makes ``cache`` the paged pool for a
    continuous-batching slot batch: x is (S, 1, d) and ``index`` the (S,)
    per-slot write positions.  Returns (y, cache) with y replicated.
    """
    if block_table is not None:
        return _decode_paged(layers, x, mesh, cache, block_table, index,
                             plan=plan)
    return _decode_dense(layers, x, mesh, cache, index, plan=plan)


def _decode_dense(layers: Sequence[Dict], x, mesh: Mesh, cache: List[Dict],
                  index, *, plan: ExecPlan):
    layers = [_validate_plan(p, None, mesh, plan)[0] for p in layers]
    backend = plan.compute_backend
    fn = shard_map(
        functools.partial(_decode_layer_local, plan=plan, backend=backend),
        mesh=mesh,
        in_specs=(layer_param_specs(), P(), CACHE_SPEC, CACHE_SPEC, P()),
        out_specs=(P(), CACHE_SPEC, CACHE_SPEC),
        check_rep=backend == "xla",
    )
    index = jnp.asarray(index, jnp.int32)
    if index.ndim == 0:
        index = jnp.broadcast_to(index, (x.shape[0],))
    new_cache = []
    for p, c in zip(layers, cache):
        x, ck, cv = fn(p, x, c["k"], c["v"], index)
        new_cache.append({"k": ck, "v": cv})
    return x, new_cache


# --- paged serving path: pool pages + block tables ----------------------------

# pool pages are (num_pages, page_size, heads, head_dim), head-sharded like
# the dense cache (same axis position), so page shards line up with the
# weight shards under any ExecPlan
PAGED_CACHE_SPEC = CACHE_SPEC


def make_paged_kv_cache(num_pages: int, page_size: int, num_layers: int,
                        mesh: Mesh, plan: ExecPlan,
                        dtype=jnp.float32) -> List[Dict]:
    """Head-sharded paged KV pool storage for a stack of HMP layers.

    Each layer holds k/v pages of global shape (num_pages, page_size,
    padded_heads, hd); the head axis carries the plan's padded layout exactly
    like ``make_kv_cache``, so a slot's gathered pages are shard-compatible
    with the dense cache.  Page 0 is the null page (``serving/kvpool.py``):
    idle-slot writes land there and masked reads never see it.
    """
    shape = (num_pages, page_size, plan.padded_heads, plan.head_dim)
    sharding = NamedSharding(mesh, PAGED_CACHE_SPEC)
    return [
        {"k": jax.device_put(jnp.zeros(shape, dtype), sharding),
         "v": jax.device_put(jnp.zeros(shape, dtype), sharding)}
        for _ in range(num_layers)
    ]


def _prefill_paged_layer_local(p, x_loc, pk, pv, phys, within, *, overlap,
                               layout: Optional[SeqLayout] = None,
                               plan: Optional[ExecPlan] = None,
                               backend: str = "xla"):
    """Prefill one layer and scatter its K/V head shards straight into pool
    pages.  phys/within: (S,) physical page and in-page slot per *absolute*
    position; under a ragged layout the valid rows are gathered out of the
    padded order first, so pad rows never touch the pool."""
    y_loc, k, v = _hmp_layer_local(p, x_loc, overlap=overlap, return_kv=True,
                                   layout=layout, plan=plan, backend=backend)
    if layout is not None:
        k, v = k[:, layout.rows], v[:, layout.rows]
    pk = pk.at[phys, within].set(k[0])
    pv = pv.at[phys, within].set(v[0])
    return y_loc, pk, pv


def _prefill_chunk_layer_local(p, x_loc, pk, pv, phys, within, block_row,
                               offset, *, overlap,
                               layout: Optional[SeqLayout] = None,
                               plan: Optional[ExecPlan] = None,
                               backend: str = "xla"):
    """Chunked-prefill step for one layer: gather the request's pages as
    attention context (positions below ``offset`` — shared prefix pages and
    earlier chunks — are valid; later rows are masked in ``_ctx_attention``),
    run the chunk, then scatter its K/V head shards into the pages at
    absolute positions.  The gather happens *before* the scatter, so the
    chunk's own keys enter attention exactly once (from the fresh K/V)."""
    page_size = pk.shape[1]
    w = block_row.shape[0]
    h_loc, hd = p["wq"].shape[1], p["wq"].shape[2]
    ctx_k = pk[block_row].reshape(w * page_size, h_loc, hd)
    ctx_v = pv[block_row].reshape(w * page_size, h_loc, hd)
    y_loc, k, v = _hmp_layer_local(p, x_loc, overlap=overlap, return_kv=True,
                                   layout=layout, plan=plan, backend=backend,
                                   ctx=(ctx_k, ctx_v, offset))
    if layout is not None:
        k, v = k[:, layout.rows], v[:, layout.rows]
    pk = pk.at[phys, within].set(k[0])
    pv = pv.at[phys, within].set(v[0])
    return y_loc, pk, pv


def _prefill_paged(layers: Sequence[Dict], x, mesh: Mesh,
                   pages: List[Dict], block_row, *, plan: ExecPlan,
                   overlap: bool, seq: Optional[int], offset):
    """Paged-pool prefill (see ``hmp_prefill``): x is (1, S, d) — the
    (bucket-padded) prompt for a dense layout, or the plan's padded ragged
    layout of a ``seq``-row prompt.  Bucket-padding positions beyond the
    real prompt write zero-token KV that decode overwrites before reading.
    K/V scatter into the block row's pages at absolute positions; with
    ``offset`` the chunk additionally gathers the block row as attention
    context (see ``_ctx_attention``).  Returns (y, pages)."""
    if x.shape[0] != 1:
        raise ValueError("paged prefill is per-request: batch must be 1")
    validated = [_validate_plan(p, x, mesh, plan, seq=seq) for p in layers]
    layers = [p for p, _ in validated]
    layout = validated[0][1] if validated else None
    s = x.shape[1] if layout is None else layout.seq
    page_size = pages[0]["k"].shape[1]
    if s > block_row.shape[0] * page_size:
        raise ValueError(
            f"prompt of {s} positions exceeds the block row "
            f"({block_row.shape[0]} pages x {page_size})"
        )
    backend = plan.compute_backend
    if offset is None:
        pos = jnp.arange(s)
        body = functools.partial(_prefill_paged_layer_local, overlap=overlap,
                                 layout=layout, plan=plan, backend=backend)
        extra_specs = ()
        extras = ()
    else:
        offset = jnp.asarray(offset, jnp.int32)
        pos = offset + jnp.arange(s)
        body = functools.partial(_prefill_chunk_layer_local, overlap=overlap,
                                 layout=layout, plan=plan, backend=backend)
        extra_specs = (P(), P())
        extras = (jnp.asarray(block_row, jnp.int32), offset)
    phys = block_row[pos // page_size].astype(jnp.int32)
    within = (pos % page_size).astype(jnp.int32)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(layer_param_specs(), P(None, AXIS, None),
                  PAGED_CACHE_SPEC, PAGED_CACHE_SPEC, P(), P(), *extra_specs),
        out_specs=(P(None, AXIS, None), PAGED_CACHE_SPEC, PAGED_CACHE_SPEC),
        check_rep=backend == "xla",
    )
    new_pages = []
    for p, c in zip(layers, pages):
        x, pk, pv = fn(p, x, c["k"], c["v"], phys, within, *extras)
        new_pages.append({"k": pk, "v": pv})
    return x, new_pages


def _paged_kv_gather(pool, block_table, head_ok):
    """Block-table gather reading only the valid head slots of real pages.

    pool: (P, page, H, hd); block_table: (S, W); head_ok: (H,) bool — which
    padded head slots hold this device's real heads.  Pad head slots' page
    reads are routed to the null page (page 0): its pad-head entries are
    zero forever (initialized zero; idle-slot writes put the projection of
    zero weights there), exactly what the old whole-page gather read out of
    real pages' pad slots — so the result is bitwise-identical while the
    gather only touches ``plan.heads[d]`` valid slots of live pages.
    Returns (S, W*page, H, hd)."""
    s, w = block_table.shape
    page, h, hd = pool.shape[1], pool.shape[2], pool.shape[3]
    bt = jnp.where(head_ok[None, None, :], block_table[:, :, None], 0)
    # advanced indices at axes 0 and 2 broadcast to (S, W, H) and land in
    # front of the kept axes: (S, W, H, page, hd)
    out = pool[bt, :, jnp.arange(h)[None, None, :], :]
    return out.transpose(0, 1, 3, 2, 4).reshape(s, w * page, h, hd)


def _decode_paged_layer_local(p, x, pk, pv, block_table, positions, *,
                              plan: Optional[ExecPlan] = None,
                              backend: str = "xla"):
    """Paged single-token TP step on one device.  x: (S, 1, d) replicated
    slot batch; block_table: (S, W) physical page per (slot, logical page);
    positions: (S,) absolute position each slot writes this step.

    Scatters the new K/V entry into its page, then gathers each slot's pages
    into a (S, W*page_size, h_loc, hd) view via the block table and attends
    under the per-slot length mask.  Idle slots carry all-null block rows:
    their write lands in the null page and every null read is masked.
    Backend routing mirrors ``_decode_layer_local``: projections shed pad
    blocks, the gather-attention core stays XLA."""
    d_model = x.shape[-1]
    h_loc, hd = p["wq"].shape[1], p["wq"].shape[2]
    page_size = pk.shape[1]
    w = block_table.shape[1]
    compute = _make_compute(backend, plan, None, None)

    q, k_new, v_new = _decode_qkv(p, x, compute)

    rows = jnp.arange(x.shape[0])
    phys = block_table[rows, positions // page_size]
    within = positions % page_size
    pk = pk.at[phys, within].set(k_new[:, 0])
    pv = pv.at[phys, within].set(v_new[:, 0])

    # gather this slot's logical context: (S, W, page, h, hd) -> (S, T, h, hd)
    if plan is not None and len(set(plan.heads)) > 1:
        # uneven heads: read only this device's valid head slots of live
        # pages — pad slots route to the (zero) null page, bitwise-equal to
        # the whole-page gather.  Even plans keep the plain gather (and its
        # exact XLA graph): every slot is valid there.
        idx = jax.lax.axis_index(AXIS)
        head_ok = jnp.arange(h_loc) < jnp.asarray(plan.heads, jnp.int32)[idx]
        ks = _paged_kv_gather(pk, block_table, head_ok)
        vs = _paged_kv_gather(pv, block_table, head_ok)
    else:
        ks = pk[block_table].reshape(x.shape[0], w * page_size, h_loc, hd)
        vs = pv[block_table].reshape(x.shape[0], w * page_size, h_loc, hd)

    scores = jnp.einsum("bqhd,bthd->bhqt", q, ks).astype(jnp.float32) / np.sqrt(hd)
    valid = jnp.arange(w * page_size)[None, :] <= positions[:, None]  # (S, T)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(vs.dtype)
    attn = jnp.einsum("bhqt,bthd->bqhd", probs, vs).reshape(*x.shape[:2], h_loc * hd)
    if compute is not None:
        g = jax.lax.psum(compute.wo_gemm(attn, p["wo"].reshape(-1, d_model)), AXIS)
    else:
        g = jax.lax.psum(attn @ p["wo"].reshape(-1, d_model), AXIS)
    return _decode_mlp_tail(p, x, g, compute), pk, pv


def _decode_paged(layers: Sequence[Dict], x, mesh: Mesh,
                  pages: List[Dict], block_table, positions, *,
                  plan: ExecPlan):
    """Paged slot-batch decode step (see ``hmp_decode``): x is (S, 1, d)
    replicated; block_table (S, W) int32; positions (S,) int32 per-slot
    absolute positions.  Returns (y, pages) with y replicated."""
    layers = [_validate_plan(p, None, mesh, plan)[0] for p in layers]
    backend = plan.compute_backend
    fn = shard_map(
        functools.partial(_decode_paged_layer_local, plan=plan, backend=backend),
        mesh=mesh,
        in_specs=(layer_param_specs(), P(), PAGED_CACHE_SPEC, PAGED_CACHE_SPEC,
                  P(), P()),
        out_specs=(P(), PAGED_CACHE_SPEC, PAGED_CACHE_SPEC),
        check_rep=backend == "xla",
    )
    block_table = jnp.asarray(block_table, jnp.int32)
    positions = jnp.asarray(positions, jnp.int32)
    new_pages = []
    for p, c in zip(layers, pages):
        x, pk, pv = fn(p, x, c["k"], c["v"], block_table, positions)
        new_pages.append({"k": pk, "v": pv})
    return x, new_pages


# --- Megatron-LM TP baseline -----------------------------------------------

def _megatron_layer_local(p, x, *, plan: Optional[ExecPlan] = None,
                          backend: str = "xla"):
    """x replicated; AllReduce after each block; connective computed
    redundantly on every device (the waste HMP eliminates).  The pallas
    backend sheds pad head/column blocks here too (x is the full dense
    sequence, so only the unit axes are ragged)."""
    h_loc, hd = p["wq"].shape[1], p["wq"].shape[2]
    d_model = x.shape[-1]
    compute = _make_compute(backend, plan, None, x.shape[1])
    q, k, v = _decode_qkv(p, x, compute)
    if compute is not None:
        attn = compute.attention(q, k, v)
        g = compute.wo_gemm(attn.reshape(*x.shape[:2], h_loc * hd),
                            p["wo"].reshape(-1, d_model))
    else:
        attn = _attention(q, k, v)
        g = jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    g = jax.lax.psum(g, AXIS)  # AllReduce #1
    x = _ln(x + g, p["ln1_s"], p["ln1_b"])  # redundant on all devices
    if compute is not None:
        h = jax.nn.gelu(compute.w1_gemm(x, p["w1"]))
        f = compute.w2_gemm(h, p["w2"])
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"]))
        f = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    f = jax.lax.psum(f, AXIS)  # AllReduce #2
    x = _ln(x + f, p["ln2_s"], p["ln2_b"])
    return x


def megatron_layer(p: Dict, x, mesh: Mesh, *, plan: Optional[ExecPlan] = None):
    p, _ = _validate_plan(p, None, mesh, plan)
    backend = plan.compute_backend if plan is not None else "xla"
    fn = shard_map(
        functools.partial(_megatron_layer_local, plan=plan, backend=backend),
        mesh=mesh,
        in_specs=(layer_param_specs(), P()),
        out_specs=P(),
        check_rep=backend == "xla",
    )
    return fn(p, x)


# --- pure Sequence Parallelism baseline ---------------------------------------

def _sp_layer_local(p, x_loc):
    """x seq-sharded; weights fully replicated (the memory wall).  K/V need
    the whole sequence: 2 AllGathers per MHA block (paper §IV-A)."""
    q = jnp.einsum("bsd,dhk->bshk", x_loc, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x_loc, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x_loc, p["wv"])
    k = jax.lax.all_gather(k, AXIS, axis=1, tiled=True)  # AllGather #1
    v = jax.lax.all_gather(v, AXIS, axis=1, tiled=True)  # AllGather #2
    # causal offset of the local query block
    idx = jax.lax.axis_index(AXIS)
    s_loc = q.shape[1]
    hd = q.shape[-1]
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) / np.sqrt(hd)
    q_pos = idx * s_loc + jnp.arange(s_loc)
    k_pos = jnp.arange(k.shape[1])
    mask = k_pos[None, :] <= q_pos[:, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    attn = jnp.einsum("bhst,bthd->bshd", probs, v)
    g = jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    x_loc = _ln(x_loc + g, p["ln1_s"], p["ln1_b"])
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x_loc, p["w1"]))
    f = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    x_loc = _ln(x_loc + f, p["ln2_s"], p["ln2_b"])
    return x_loc


def sp_layer(p: Dict, x, mesh: Mesh, *, plan: Optional[ExecPlan] = None):
    # SP replicates weights: an uneven TP plan does not apply
    fn = shard_map(
        _sp_layer_local,
        mesh=mesh,
        in_specs=(layer_param_specs(sp=True), P(None, AXIS, None)),
        out_specs=P(None, AXIS, None),
    )
    return fn(p, x)


SCHEDULES = {
    "hmp": lambda p, x, mesh, **kw: hmp_layer(p, x, mesh, overlap=False, **kw),
    "hmp_ring": lambda p, x, mesh, **kw: hmp_layer(p, x, mesh, overlap=True, **kw),
    "megatron": lambda p, x, mesh, **kw: megatron_layer(p, x, mesh, **kw),
    "sp": lambda p, x, mesh, **kw: sp_layer(p, x, mesh, **kw),
}
