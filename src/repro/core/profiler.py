"""Galaxy Profiler (paper §III-A step 1).

Produces the run-time traces the planner consumes:

* per-device capacity V_d (Eq. 6): inverse time of one full MHA + MLP block
* per-block memory footprints (M_att, M_mlp)
* per-partition-configuration latency tables L(T, C_d, d)

Two backends:
- ``AnalyticProfiler`` — the calibrated cost model (simulated Jetson
  clusters; used by the planner + the paper-table simulator).
- ``HostProfiler``   — times real jitted blocks on this host (used in
  examples/tests to demonstrate the profiling workflow end-to-end).
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import costmodel
from repro.core.costmodel import DeviceSpec
from repro.core.planner import DeviceProfile, ModelProfile


class AnalyticProfiler:
    def __init__(self, cfg: ModelConfig, seq: int):
        self.cfg = cfg
        self.seq = seq
        self.prof = costmodel.layer_profile(cfg, seq)

    def capacity(self, dev: DeviceSpec) -> float:
        """V_d per Eq. 6 (1/seconds for the full MHA+MLP blocks)."""
        t = (self.prof["mha_flops"] + self.prof["mlp_flops"]) / dev.flops
        return 1.0 / t

    def device_profiles(self, devices: Sequence[DeviceSpec]) -> List[DeviceProfile]:
        return [
            DeviceProfile(d.name, self.capacity(d), d.memory_budget) for d in devices
        ]

    def model_profile(self) -> ModelProfile:
        cfg = self.cfg
        return ModelProfile(
            name=cfg.name,
            num_layers=cfg.num_layers,
            num_heads=cfg.num_heads,
            mlp_columns=cfg.d_ff,
            m_att=self.prof["m_att"],
            m_mlp=self.prof["m_mlp"],
        )

    def block_latency(self, block: str, frac: float, dev: DeviceSpec) -> float:
        """L(T, C_d, d) for a fractional partition (paper's latency table)."""
        if block == "mha":
            return frac * self.prof["mha_flops"] / dev.flops
        if block == "mlp":
            return frac * self.prof["mlp_flops"] / dev.flops
        if block == "con":
            return frac * self.prof["con_bytes"] / dev.mem_bw
        raise ValueError(block)

    def seq_cost_args(self, devices: Sequence[DeviceSpec]) -> Dict[str, object]:
        """Per-row costs of the SP axis, for ``planner.sequence_partition``:
        activation bytes one row moves per ring hop, and the seconds of
        (memory-bandwidth-bound) connective work one row costs per device."""
        return {
            "unit_bytes": self.prof["act_bytes"] / self.seq,
            "unit_con_time": [
                (self.prof["con_bytes"] / self.seq) / d.mem_bw for d in devices
            ],
        }

    def plan(self, devices: Sequence[DeviceSpec], links=None,
             pad_penalty: float = 0.0):
        """Run Algorithm 1 from this profile; with per-device ``links``
        (``costmodel.LinkSpec``) the SP axis is solved bandwidth-aware over
        this profiler's sequence length (ragged sequence tiles).
        ``pad_penalty`` forwards to ``planner.plan`` — regularize the unit
        partitions against ``max(units)`` pad spread."""
        from repro.core import planner

        kwargs = {}
        if links is not None:
            kwargs = dict(seq_units=self.seq, **self.seq_cost_args(devices))
        return planner.plan(self.model_profile(), self.device_profiles(devices),
                            links, pad_penalty=pad_penalty, **kwargs)


class HostProfiler:
    """Times real jitted MHA/MLP blocks on the current host (calibration-
    data-driven, as the paper's profiler runs on the physical devices)."""

    def __init__(self, cfg: ModelConfig, seq: int, iters: int = 5):
        self.cfg = cfg
        self.seq = seq
        self.iters = iters

    def _time(self, fn, *args) -> float:
        fn_j = jax.jit(fn)
        out = fn_j(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(self.iters):
            out = fn_j(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / self.iters

    def measure_blocks(self, heads: int, columns: int) -> Dict[str, float]:
        """Measured L(MHA, a, host), L(MLP, b, host), L(CON, full, host)."""
        cfg, s = self.cfg, self.seq
        d, hd = cfg.d_model, cfg.head_dim
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (1, s, d), jnp.float32)
        wqkv = jax.random.normal(key, (d, 3 * heads * hd), jnp.float32)
        wo = jax.random.normal(key, (heads * hd, d), jnp.float32)
        w1 = jax.random.normal(key, (d, columns), jnp.float32)
        w2 = jax.random.normal(key, (columns, d), jnp.float32)

        def mha(x, wqkv, wo):
            qkv = x @ wqkv
            q, k, v = jnp.split(qkv, 3, -1)
            q = q.reshape(1, s, heads, hd)
            k = k.reshape(1, s, heads, hd)
            v = v.reshape(1, s, heads, hd)
            sc = jnp.einsum("bshd,bthd->bhst", q, k) / jnp.sqrt(hd).astype(x.dtype)
            p = jax.nn.softmax(sc, -1)
            o = jnp.einsum("bhst,bthd->bshd", p, v).reshape(1, s, heads * hd)
            return o @ wo

        def mlp(x, w1, w2):
            return jax.nn.gelu(x @ w1) @ w2

        def con(x):
            mu = x.mean(-1, keepdims=True)
            var = ((x - mu) ** 2).mean(-1, keepdims=True)
            return x + (x - mu) * jax.lax.rsqrt(var + 1e-5)

        return {
            "mha": self._time(mha, x, wqkv, wo),
            "mlp": self._time(mlp, x, w1, w2),
            "con": self._time(con, x),
        }

    def capacity(self) -> float:
        t = self.measure_blocks(self.cfg.num_heads, self.cfg.d_ff)
        return 1.0 / (t["mha"] + t["mlp"])
