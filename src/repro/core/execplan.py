"""Execution plans: materialize a ``planner.Plan`` into a runnable program.

The planner (Alg. 1) emits *uneven* integer shard counts — heads per device
for MHA, columns per device for MLP — but SPMD ``shard_map`` programs need
equal per-device shapes.  An :class:`ExecPlan` closes that gap with
pad-and-mask materialization:

* every device's head slice is padded to ``max(heads)`` and every column
  slice to ``max(columns)`` with **zeroed weights**, so the math stays exact
  (zero ``wo`` rows / ``w2`` rows contribute nothing to the block output);
* the sequence axis gets the same treatment (:class:`SeqLayout`): the
  planner's uneven per-device sequence tiles are padded to ``max(tile)``
  rows, real rows scattered to per-device offsets, and the pad rows masked
  out of the ragged ring schedule (``core/ring.py``) and the attention mask
  — any sequence length runs on any mesh, no divisibility required.

The same ExecPlan object is consumed by the executor (``core/hmp.py``), the
serving engine (``serving/galaxy.py``), the simulator
(``core/simulator.simulate_execplan``) and the microbenchmarks, so a plan is
scored and executed as *one* artifact.

Note the honesty cost of padding: under SPMD every device executes
``max(units)`` worth of dense GEMM even if it was assigned fewer units.
``compute_fractions(padded=True)`` exposes that executed (as opposed to
assigned) workload so the simulator can score both views.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planner
from repro.core.ring import BUCKETS_PER_TILE, RING_TRANSPORTS, RingSchedule

# which axis of each layer parameter is partitioned, and by which unit kind;
# the PartitionSpecs themselves live in hmp.layer_param_specs (identical for
# even and padded layouts)
_PARTITIONED_AXES = {
    "wq": ("head", 1),
    "wk": ("head", 1),
    "wv": ("head", 1),
    "wo": ("head", 0),
    "w1": ("column", 1),
    "w2": ("column", 0),
}


@dataclasses.dataclass(frozen=True)
class SeqLayout:
    """Padded ragged layout of one global sequence over the ring devices.

    ``tiles[d]`` real rows belong to device ``d`` (summing to the logical
    sequence length); every device's shard is padded to ``pad_tile =
    max(tiles)`` rows so shard_map shapes stay SPMD-equal.  Real position
    ``p`` lives at padded row ``rows[p]``; pad rows carry no position
    (``positions == -1``) and are masked out of attention and the ring
    schedule.  For an equal split of a dividing sequence the layout is
    *dense* (``is_dense``): scatter/gather are identities and the executor
    takes the exact pre-ragged code path.
    """

    tiles: Tuple[int, ...]

    @property
    def num_devices(self) -> int:
        return len(self.tiles)

    @property
    def seq(self) -> int:
        """Logical (unpadded) sequence length: sum of the valid tiles."""
        return sum(self.tiles)

    @property
    def pad_tile(self) -> int:
        """Rows each device's shard holds after padding."""
        return max(self.tiles)

    @property
    def padded_len(self) -> int:
        return self.num_devices * self.pad_tile

    @property
    def is_dense(self) -> bool:
        return self.padded_len == self.seq

    @functools.cached_property
    def offsets(self) -> np.ndarray:
        """(D,) first real position owned by each device."""
        return np.concatenate([[0], np.cumsum(self.tiles)[:-1]]).astype(int)

    @functools.cached_property
    def rows(self) -> np.ndarray:
        """(seq,) padded-row index of each real position."""
        return np.concatenate(
            [d * self.pad_tile + np.arange(t, dtype=int)
             for d, t in enumerate(self.tiles)]
        ) if self.seq else np.zeros(0, int)

    @functools.cached_property
    def positions(self) -> np.ndarray:
        """(padded_len,) real position of each padded row; -1 for pad rows."""
        pos = np.full(self.padded_len, -1, int)
        pos[self.rows] = np.arange(self.seq)
        return pos

    @functools.cached_property
    def valid(self) -> np.ndarray:
        """(padded_len,) bool: which padded rows hold real positions."""
        return self.positions >= 0

    def attention_mask(self) -> np.ndarray:
        """(padded_len, padded_len) bool causal mask in the padded domain.

        Real query rows attend causally to real key rows; pad query rows
        attend everywhere (their garbage stays confined to pad rows and an
        all-masked softmax row would go NaN)."""
        pos = self.positions
        causal = self.valid[None, :] & (pos[None, :] <= pos[:, None])
        return np.where(self.valid[:, None], causal, True)

    def scatter(self, x):
        """(B, seq, ...) real layout -> (B, padded_len, ...) padded layout
        (pad rows zero).  Identity for dense layouts."""
        if self.is_dense:
            return x
        shape = (x.shape[0], self.padded_len, *x.shape[2:])
        return jnp.zeros(shape, x.dtype).at[:, self.rows].set(x)

    def gather(self, y):
        """(B, padded_len, ...) padded layout -> (B, seq, ...) real layout."""
        if self.is_dense:
            return y
        return y[:, self.rows]

    def padding_waste(self) -> float:
        """Fraction of executed sequence rows that are pad."""
        return 1.0 - self.seq / self.padded_len


# pluggable per-shard compute path — the registry lives with the dispatch
# (kernels/ops.py), re-exported here for plan-level callers:
#   "xla"    — padded dense einsums; pad slots are zero weights, every device
#              executes max(units) dense work (the correctness oracle)
#   "pallas" — valid-length kernels; per-device valid counts enter as
#              scalar-prefetch operands and the grids skip pad blocks, so
#              executed MXU work tracks the assigned units
from repro.kernels.ops import COMPUTE_BACKENDS  # noqa: E402


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    """A runnable materialization of one layer-parallel partition.

    heads:      MHA heads assigned per device (sums to the model's head count)
    columns:    MLP columns assigned per device (sums to d_ff)
    seq_shares: relative sequence-tile weights per device (the planner's
                ``Plan.seq``); empty means the equal split.  Normalized at
                use; materialized per sequence length by ``seq_layout``.
    compute_backend: which per-shard compute path the executor runs
                (``COMPUTE_BACKENDS``); "pallas" sheds pad-block work.
    transport:  ring wire format (``ring.RING_TRANSPORTS``): "padded" ships
                whole ``max(tiles)``-row tiles per hop, "bucketed" ships
                bucket-rounded ~valid rows (``RingSchedule.ragged``).
    double_buffer: issue each ring hop before the GEMM that frees its
                buffer (explicit tile-level overlap, ``core/ring.py``).
    """

    heads: Tuple[int, ...]
    columns: Tuple[int, ...]
    head_dim: int
    d_model: int
    seq_shares: Tuple[float, ...] = ()
    compute_backend: str = "xla"
    transport: str = "padded"
    double_buffer: bool = False

    def __post_init__(self):
        if self.compute_backend not in COMPUTE_BACKENDS:
            raise ValueError(
                f"unknown compute_backend {self.compute_backend!r}; "
                f"one of {COMPUTE_BACKENDS}"
            )
        if self.transport not in RING_TRANSPORTS:
            raise ValueError(
                f"unknown transport {self.transport!r}; "
                f"one of {RING_TRANSPORTS}"
            )
        if len(self.heads) != len(self.columns):
            raise ValueError(
                f"heads ({len(self.heads)}) and columns ({len(self.columns)}) "
                "must cover the same device list"
            )
        if not self.heads:
            raise ValueError("ExecPlan needs at least one device")
        if min(self.heads) < 0 or min(self.columns) < 0:
            raise ValueError("shard counts must be non-negative")
        if max(self.heads) == 0 or max(self.columns) == 0:
            raise ValueError("at least one device must hold a nonzero shard")
        if self.seq_shares:
            if len(self.seq_shares) != len(self.heads):
                raise ValueError(
                    f"seq_shares ({len(self.seq_shares)}) must cover the "
                    f"same {len(self.heads)} devices"
                )
            if min(self.seq_shares) < 0 or sum(self.seq_shares) <= 0:
                raise ValueError("seq_shares must be non-negative, sum > 0")

    # --- constructors ---------------------------------------------------------
    @classmethod
    def from_plan(cls, plan_: planner.Plan, *, head_dim: int, d_model: int,
                  compute_backend: str = "xla") -> "ExecPlan":
        if not plan_.feasible:
            raise ValueError(f"cannot materialize an infeasible plan: {plan_.reason}")
        return cls(
            heads=tuple(int(a) for a in plan_.mha),
            columns=tuple(int(b) for b in plan_.mlp),
            head_dim=head_dim,
            d_model=d_model,
            seq_shares=tuple(float(s) for s in plan_.seq),
            compute_backend=compute_backend,
        )

    def with_backend(self, compute_backend: str) -> "ExecPlan":
        """The same plan routed through another per-shard compute path."""
        return dataclasses.replace(self, compute_backend=compute_backend)

    def with_transport(self, transport: str = None, *,
                       double_buffer: bool = None) -> "ExecPlan":
        """The same plan with a different ring wire format / overlap mode."""
        return dataclasses.replace(
            self,
            transport=self.transport if transport is None else transport,
            double_buffer=(self.double_buffer if double_buffer is None
                           else double_buffer),
        )

    @classmethod
    def even(cls, n: int, *, num_heads: int, d_ff: int, head_dim: int,
             d_model: int) -> "ExecPlan":
        """Equal-split plan (what the pre-ExecPlan executor hard-coded)."""
        if num_heads % n or d_ff % n:
            raise ValueError(f"{num_heads} heads / {d_ff} columns do not split evenly over {n}")
        return cls((num_heads // n,) * n, (d_ff // n,) * n, head_dim, d_model)

    # --- derived geometry -----------------------------------------------------
    @property
    def num_devices(self) -> int:
        return len(self.heads)

    @property
    def num_heads(self) -> int:
        return sum(self.heads)

    @property
    def d_ff(self) -> int:
        return sum(self.columns)

    @property
    def pad_heads(self) -> int:
        """Per-device head slots after padding (= straggler's head count)."""
        return max(self.heads)

    @property
    def pad_columns(self) -> int:
        return max(self.columns)

    @property
    def padded_heads(self) -> int:
        """Global head count of the padded parameter arrays."""
        return self.num_devices * self.pad_heads

    @property
    def padded_ff(self) -> int:
        return self.num_devices * self.pad_columns

    @property
    def is_even(self) -> bool:
        return len(set(self.heads)) == 1 and len(set(self.columns)) == 1

    # --- sequence geometry (ragged SP axis) -----------------------------------
    @property
    def seq_fractions(self) -> np.ndarray:
        """(D,) normalized sequence shares; equal split when unset."""
        if not self.seq_shares:
            return np.full(self.num_devices, 1.0 / self.num_devices)
        s = np.asarray(self.seq_shares, float)
        return s / s.sum()

    @property
    def uneven_seq(self) -> bool:
        f = self.seq_fractions
        return bool(np.ptp(f) > 1e-12)

    def seq_tiles(self, seq: int) -> Tuple[int, ...]:
        """Integer per-device sequence tiles for a given length (sum = seq)."""
        return tuple(
            int(t) for t in planner._largest_remainder_round(
                self.seq_fractions * seq, seq)
        )

    def seq_layout(self, seq: int) -> SeqLayout:
        """Padded ragged layout of a ``seq``-row sequence under this plan."""
        return SeqLayout(self.seq_tiles(seq))

    def seq_tile(self, seq: int) -> int:
        """Per-device sequence rows after padding (= the straggler's tile)."""
        return self.seq_layout(seq).pad_tile

    def padded_seq(self, seq: int) -> int:
        """Global rows of the padded ragged layout (= D * seq_tile)."""
        return self.seq_layout(seq).padded_len

    @property
    def seq_grain(self) -> int:
        """Preferred prompt-length bucketing grain for serving.  Correctness
        no longer needs any padding — ``seq_layout`` covers every length —
        so this only bounds the number of distinct compiled prefill shapes."""
        return self.num_devices

    # --- ring transport (what the hops ship) ----------------------------------
    def ring_schedule(self, seq: int = None, *, layout: SeqLayout = None,
                      gemm=None) -> RingSchedule:
        """The ring program this plan's hops run for one sequence.

        Solved ahead of trace time from ``seq_shares``: tile geometry from
        ``seq_layout``, wire format and overlap mode from the plan's
        ``transport`` / ``double_buffer`` knobs."""
        if layout is None:
            if seq is None:
                raise ValueError("ring_schedule needs seq= or layout=")
            layout = self.seq_layout(seq)
        return RingSchedule.ragged(
            layout.tiles, pad_tile=layout.pad_tile, transport=self.transport,
            double_buffer=self.double_buffer, gemm=gemm,
        )

    def wire_fractions(self) -> np.ndarray:
        """(D,) fraction of the logical sequence each device's hop ships, in
        the large-seq limit (tiles -> shares).  Padded transport always
        ships the straggler's ``max(fraction)`` tile; bucketed transport
        ships each tile rounded up to the ``BUCKETS_PER_TILE`` grain —
        the same rounding ``RingSchedule.ragged`` applies to integer
        tiles."""
        f = self.seq_fractions
        top = float(f.max())
        if self.transport != "bucketed":
            return np.full(self.num_devices, top)
        grain = top / BUCKETS_PER_TILE
        return np.minimum(top, np.ceil(f / grain - 1e-9) * grain)

    # --- masks ----------------------------------------------------------------
    def head_mask(self) -> np.ndarray:
        """Bool (padded_heads,): which padded head slots hold real heads."""
        m = np.zeros(self.padded_heads, bool)
        for d, c in enumerate(self.heads):
            m[d * self.pad_heads : d * self.pad_heads + c] = True
        return m

    def column_mask(self) -> np.ndarray:
        m = np.zeros(self.padded_ff, bool)
        for d, c in enumerate(self.columns):
            m[d * self.pad_columns : d * self.pad_columns + c] = True
        return m

    # --- parameter materialization --------------------------------------------
    def _counts(self, kind: str) -> Tuple[Sequence[int], int]:
        return (self.heads, self.pad_heads) if kind == "head" else (
            self.columns, self.pad_columns)

    def _pad_axis(self, arr, kind: str, axis: int):
        counts, pad = self._counts(kind)
        shape = list(arr.shape)
        shape[axis] = len(counts) * pad
        out = jnp.zeros(shape, arr.dtype)
        off = 0
        for d, c in enumerate(counts):
            if c:
                src = jax.lax.slice_in_dim(arr, off, off + c, axis=axis)
                out = jax.lax.dynamic_update_slice_in_dim(out, src, d * pad, axis)
                off += c
        return out

    def pad_layer_params(self, p: Dict) -> Dict:
        """Reference-layout layer params -> device-contiguous padded params.

        Device ``d`` owns heads ``[sum(heads[:d]), sum(heads[:d+1]))`` of the
        original arrays, placed at slots ``[d*pad_heads, ...)`` of the padded
        arrays; pad slots are zero, so every block's output is exact.
        """
        self._check_reference(p)
        out = dict(p)
        for name, (kind, axis) in _PARTITIONED_AXES.items():
            out[name] = self._pad_axis(p[name], kind, axis)
        return out

    def _check_reference(self, p: Dict) -> None:
        if p["wq"].shape[1] != self.num_heads or p["wq"].shape[2] != self.head_dim:
            raise ValueError(
                f"params have {p['wq'].shape[1]}x{p['wq'].shape[2]} heads, "
                f"plan expects {self.num_heads}x{self.head_dim}"
            )
        if p["w1"].shape[1] != self.d_ff:
            raise ValueError(
                f"params have d_ff={p['w1'].shape[1]}, plan expects {self.d_ff}"
            )

    def is_padded(self, p: Dict) -> bool:
        """True if ``p`` is already in this plan's padded layout."""
        return (
            p["wq"].shape[1] == self.padded_heads
            and p["w1"].shape[1] == self.padded_ff
        )

    def ensure_padded(self, p: Dict) -> Dict:
        """Accept either layout; return padded params."""
        if self.is_padded(p):
            return p
        return self.pad_layer_params(p)

    # --- paged KV geometry ----------------------------------------------------
    def kv_page_bytes(self, page_size: int, dtype_bytes: int = 4) -> int:
        """Bytes of one K+V pool page for one layer under this plan's padded
        head layout — what ``hmp.make_paged_kv_cache`` allocates per page.
        Padded head slots are dead weight here too: page memory scales with
        ``padded_heads``, not ``num_heads``."""
        return 2 * page_size * self.padded_heads * self.head_dim * dtype_bytes

    # --- scoring hooks --------------------------------------------------------
    def compute_fractions(self, padded: bool = False):
        """(mha_frac, mlp_frac): per-device share of each block's total work.

        ``padded=False`` is the planner's assigned workload (paper Eq. 4/5);
        ``padded=True`` is what the SPMD program actually executes — every
        device runs ``max(units)`` dense units, zeros included.
        """
        if padded:
            a = np.full(self.num_devices, self.pad_heads / self.num_heads)
            b = np.full(self.num_devices, self.pad_columns / self.d_ff)
        else:
            a = np.asarray(self.heads) / self.num_heads
            b = np.asarray(self.columns) / self.d_ff
        return a, b

    def to_planner_plan(self, padded: bool = False) -> planner.Plan:
        """Re-express as a ``planner.Plan`` for simulator/objective scoring.

        ``padded=True`` is the SPMD execution view.  With the "xla" backend
        that is pad-and-mask on *every* axis: each device runs
        ``max(units)`` heads/columns and holds (and ppermutes) the
        straggler's ``max(fraction)`` sequence tile.  With the "pallas"
        backend the valid-length kernels shed pad compute, so the compute
        axes score *effective* units (block-rounding ignored) — only the
        transport/connective side still carries the straggler's padded
        sequence tile (SPMD ppermutes whole equal-shaped tiles either
        way)."""
        n = self.num_devices
        shed = padded and self.compute_backend == "pallas"
        dense = not padded or shed
        heads = np.asarray(self.heads) if dense else np.full(n, self.pad_heads)
        cols = np.asarray(self.columns) if dense else np.full(n, self.pad_columns)
        frac = self.seq_fractions
        seq = np.full(n, float(frac.max())) if padded else frac
        # bucketed transport ships bucket-rounded rows regardless of the
        # compute view; padded transport prices whatever ``seq`` carries
        wire = self.wire_fractions() if self.transport == "bucketed" else None
        return planner.Plan(
            mha=heads.astype(int), mlp=cols.astype(int),
            seq=seq, feasible=True, seq_wire=wire,
        )

    def device_gemm_flops(self, seq: int = 1, padded: bool = False) -> np.ndarray:
        """(D,) dense per-shard GEMM FLOPs of one layer over ``seq`` rows.

        Units are priced by ``costmodel.gemm_unit_flops``.  ``padded=True``
        is what a non-shedding SPMD program executes — every device at
        ``max(units)``; the default is the assigned workload a pad-shedding
        backend actually runs."""
        from repro.core import costmodel

        unit = costmodel.gemm_unit_flops(self.d_model, self.head_dim)
        head_flops, col_flops = unit["head"], unit["column"]
        heads = np.full(self.num_devices, self.pad_heads) if padded \
            else np.asarray(self.heads)
        cols = np.full(self.num_devices, self.pad_columns) if padded \
            else np.asarray(self.columns)
        return seq * (heads * head_flops + cols * col_flops).astype(float)

    def prefill_gemm_flops(self, seq: int, cached_prefix: int = 0,
                           padded: bool = False) -> np.ndarray:
        """(D,) per-shard GEMM FLOPs of one layer's prefill when the leading
        ``cached_prefix`` positions are shared-prefix KV-cache hits
        (``serving/prefix_cache.py``): projections and MLP run only over the
        uncached suffix rows — the prefix KV is gathered from shared pages,
        not recomputed.  The attention core (not a GEMM here) still reads
        the full context; ``simulate_execplan(cached_prefix=)`` prices that
        term."""
        if not 0 <= cached_prefix < seq:
            raise ValueError(
                f"cached_prefix {cached_prefix} must lie in [0, seq={seq})"
            )
        return self.device_gemm_flops(seq - cached_prefix, padded=padded)

    def flops_shed(self) -> float:
        """Fraction of padded dense GEMM FLOPs a shedding backend skips
        (FLOPs-weighted counterpart of the unit-count ``padding_waste``)."""
        eff = self.device_gemm_flops().sum()
        pad = self.device_gemm_flops(padded=True).sum()
        return 1.0 - eff / pad

    def describe(self) -> str:
        f = self.seq_fractions
        if self.uneven_seq:
            seq = ("seq=[" + ",".join(f"{x:.0%}" for x in f)
                   + f"] (sp_waste={self.seq_padding_waste():.1%})")
        else:
            seq = "seq=equal"
        eff = self.device_gemm_flops()
        pad = self.device_gemm_flops(padded=True)
        flops = ",".join(f"{e / p:.0%}" for e, p in zip(eff, pad))
        transport = self.transport + ("+db" if self.double_buffer else "")
        if self.transport == "bucketed":
            top = float(self.seq_fractions.max())
            shipped = self.wire_fractions().sum() / (self.num_devices * top)
            transport += f" (wire={shipped:.0%})"
        return (
            f"ExecPlan(n={self.num_devices}, heads={list(self.heads)}"
            f"->pad {self.pad_heads}, columns={list(self.columns)}"
            f"->pad {self.pad_columns}, {seq}, waste="
            f"{self.padding_waste():.1%}, eff/pad flops=[{flops}], "
            f"backend={self.compute_backend}, transport={transport})"
        )

    def padding_waste(self) -> float:
        """Fraction of executed dense FLOPs that are zero padding."""
        real = self.num_heads + self.d_ff
        executed = self.padded_heads + self.padded_ff
        return 1.0 - real / executed

    def seq_padding_waste(self) -> float:
        """Fraction of executed sequence rows that are pad, in the large-seq
        limit (tiles -> shares): 1 - 1 / (D * max(fraction))."""
        return 1.0 - 1.0 / (self.num_devices * float(self.seq_fractions.max()))
