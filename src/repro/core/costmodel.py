"""Analytic device/link cost model.

Calibrated against the paper's own measurements (§II-B Table I):
* Bert-L (24L, d=1024) at seq 30 on Nano-M (0.825 GHz) takes 2.43 s
  -> ~7.1 GFLOP/s effective, i.e. ~8.6 GFLOP/s per GHz of the quad A53.
  The same constant predicts DistilBert at 0.36 s (paper: 0.37 s).
* Memory footprints are fp16 parameter bytes (DistilBert 132 MB ~ paper
  130 MB, Bert-L 680 MB = paper 680 MB, OPT-XL 5.4 GB = paper 5.4 GB).

TPU v5e constants are the roofline terms' denominators (task spec):
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Union

from repro.configs.base import ModelConfig

# --- edge devices ------------------------------------------------------------

GFLOPS_PER_GHZ = 8.6e9           # calibrated vs paper Table I (CPU mode)
NANO_MEM_BW = 4.0e9              # effective LPDDR4 bandwidth under CPU load
NANO_GPU_GFLOPS = 120e9          # 128-core Maxwell @460MHz, ~fp16 effective
BYTES_FP16 = 2
# The paper's prototype (PyTorch + gloo on CPU) synchronizes fp32 activation
# tensors even when weights are fp16 — gloo has no fp16 ring collectives.
BYTES_ACT = 4


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    name: str
    flops: float            # effective FLOP/s
    mem_bw: float           # effective bytes/s
    memory_budget: float    # bytes usable for weights


def jetson_nano(kind: str, memory_budget_gb: float) -> DeviceSpec:
    freq = {"nano-l": 1.47e9, "nano-m": 0.825e9, "nano-s": 0.403e9}[kind]
    return DeviceSpec(
        name=kind,
        flops=GFLOPS_PER_GHZ * freq / 1e9,
        mem_bw=NANO_MEM_BW,
        memory_budget=memory_budget_gb * 1e9,
    )


def jetson_nano_gpu(memory_budget_gb: float = 1.5) -> DeviceSpec:
    return DeviceSpec("nano-gpu", NANO_GPU_GFLOPS, 12e9, memory_budget_gb * 1e9)


# paper Table III edge environments
def edge_env(env_id: str) -> list:
    n = jetson_nano
    return {
        "A": [n("nano-m", 1.5)] * 2,
        "B": [n("nano-m", 1.5)] * 3,
        "C": [n("nano-m", 1.5)] * 4,
        "D": [n("nano-l", 1.5), n("nano-m", 1.2)],
        "E": [n("nano-l", 1.5), n("nano-s", 0.7)],
        "F": [n("nano-l", 1.5), n("nano-m", 1.2), n("nano-s", 0.7)],
    }[env_id]


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    bandwidth: float        # bytes/s
    latency: float = 1e-3   # per-hop software+switch latency (Ethernet)


def mbps(x: float) -> LinkSpec:
    return LinkSpec(bandwidth=x * 1e6 / 8)


# A cluster's links can be heterogeneous: ``Links`` is either one LinkSpec
# (every hop identical, the pre-ragged behavior) or one LinkSpec per device —
# entry i is the *outgoing* link of ring device i (i -> i+1 mod D).
Links = Union[LinkSpec, Sequence[LinkSpec]]


def as_ring_links(link: Links, d: int) -> List[LinkSpec]:
    """Normalize to one outgoing LinkSpec per ring device."""
    if isinstance(link, LinkSpec):
        return [link] * d
    links = list(link)
    if len(links) != d:
        raise ValueError(f"{len(links)} links for a ring of {d} devices")
    return links


def bottleneck_link(link: Links, d: int) -> LinkSpec:
    """Slowest hop: what gates a synchronized full-tensor ring collective."""
    return min(as_ring_links(link, d), key=lambda l: l.bandwidth)


def t_ring_exchange(tile_bytes: Sequence[float], link: Links) -> float:
    """Total time of one D-1-step ring rotation of (possibly uneven) tiles.

    At step r device i forwards the tile originally owned by device
    (i - r) mod D over its outgoing link; the step completes when the
    slowest (tile bytes / link) pair finishes.  With equal tiles and a
    uniform link this reduces exactly to ``t_allgather``/``t_reducescatter``
    of the concatenated tensor.  Uneven tiles are the ragged-SP case: a
    real edge deployment sends only each tile's valid rows (point-to-point
    transports carry exact sizes), so a bandwidth-aware seq split shrinks
    the bytes crossing slow links.
    """
    d = len(tile_bytes)
    if d <= 1:
        return 0.0
    links = as_ring_links(link, d)
    total = 0.0
    for r in range(d - 1):
        total += max(
            tile_bytes[(i - r) % d] / links[i].bandwidth + links[i].latency
            for i in range(d)
        )
    return total


# --- TPU v5e (roofline targets) -------------------------------------------------

TPU_V5E = {
    "peak_flops": 197e12,     # bf16
    "hbm_bw": 819e9,          # bytes/s
    "ici_bw": 50e9,           # bytes/s per link
    "hbm_bytes": 16e9,
}


# --- collective cost (ring algorithms, paper §III-B-5) ----------------------------

def t_allgather(n_bytes: float, d: int, link: LinkSpec) -> float:
    """Ring AllGather of a global tensor of n_bytes (each device holds n/D)."""
    if d <= 1:
        return 0.0
    return (d - 1) / d * n_bytes / link.bandwidth + (d - 1) * link.latency


def t_reducescatter(n_bytes: float, d: int, link: LinkSpec) -> float:
    if d <= 1:
        return 0.0
    return (d - 1) / d * n_bytes / link.bandwidth + (d - 1) * link.latency


def t_allreduce(n_bytes: float, d: int, link: LinkSpec) -> float:
    """Ring AllReduce = ReduceScatter + AllGather (paper §III-B-5)."""
    return t_allgather(n_bytes, d, link) + t_reducescatter(n_bytes, d, link)


# --- per-layer workload profile of a paper-style Transformer layer ----------------

def layer_profile(cfg: ModelConfig, seq: int) -> Dict[str, float]:
    """FLOPs / bytes of one Transformer layer (Fig. 2) at a sequence length."""
    d, ff, h = cfg.d_model, cfg.d_ff, cfg.num_heads
    hd = cfg.head_dim
    kv = cfg.num_kv_heads
    qkvo_flops = 2 * seq * d * (h * hd + 2 * kv * hd) + 2 * seq * (h * hd) * d
    attn_flops = 2 * 2 * seq * seq * h * hd
    gate = 3 if cfg.activation in ("swiglu", "geglu") else 2
    mlp_flops = gate * 2 * seq * d * ff
    # connective: dropout + residual + layernorm, ~4 passes over activations
    con_bytes = 2 * 4 * seq * d * BYTES_ACT * 2
    m_att = (d * (h * hd + 2 * kv * hd) + (h * hd) * d) * BYTES_FP16
    m_mlp = gate * d * ff * BYTES_FP16
    return {
        "mha_flops": qkvo_flops + attn_flops,
        "mlp_flops": mlp_flops,
        "con_bytes": con_bytes,
        "m_att": m_att,
        "m_mlp": m_mlp,
        "act_bytes": seq * d * BYTES_ACT,
    }


def gemm_unit_flops(d_model: int, head_dim: int) -> Dict[str, float]:
    """Dense GEMM FLOPs one partition unit costs per sequence row.

    One MHA head: its QKV projection columns (3 x 2·d·hd) plus its WO rows
    (2·hd·d).  One MLP column: its W1 column (2·d) plus its W2 row (2·d).
    These are the weights that convert unit counts into the effective-FLOPs
    view a pad-shedding backend executes (``ExecPlan.device_gemm_flops``,
    the planner's pad regularizer, and the ``execplan_padshed`` bench all
    price units with this).
    """
    return {"head": 8 * d_model * head_dim, "column": 4 * d_model}


def model_memory_bytes(cfg: ModelConfig) -> float:
    prof = layer_profile(cfg, 1)
    embed = cfg.vocab_size * cfg.d_model * BYTES_FP16
    return cfg.num_layers * (prof["m_att"] + prof["m_mlp"]) + embed


# --- speculative decoding (serving/spec.py) -----------------------------------

def spec_expected_tokens(acceptance: float, k: int) -> float:
    """Expected tokens emitted per speculative round with k drafts.

    A round emits the longest accepted draft prefix plus one token from the
    verifier itself (the correction on a mismatch, the bonus row when all k
    match).  Modeling per-position agreement as i.i.d. with probability
    ``acceptance``, the emitted count is ``1 + min(Geom, k)`` and its mean
    telescopes to ``(1 - a^(k+1)) / (1 - a)`` — between 1 (a=0: every round
    still emits the verifier's own token) and k+1 (a=1: every draft lands).
    """
    if not 0.0 <= acceptance <= 1.0:
        raise ValueError(f"acceptance {acceptance} must lie in [0, 1]")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if acceptance == 1.0:
        return float(k + 1)
    return (1.0 - acceptance ** (k + 1)) / (1.0 - acceptance)


# --- calibration hooks (experiments/calibrate.py) ----------------------------

# constants the measured-vs-simulated loop may override, and where they live;
# TILE_OVERHEAD belongs to the simulator (which imports this module) so it is
# resolved lazily to avoid a load-time cycle
_CALIBRATABLE = ("GFLOPS_PER_GHZ", "NANO_MEM_BW", "BYTES_ACT", "TILE_OVERHEAD")


def apply_calibration(overrides: Dict[str, float]) -> Dict[str, float]:
    """Override calibratable cost-model constants; returns the previous
    values so a calibration experiment can restore them (try/finally)."""
    unknown = set(overrides) - set(_CALIBRATABLE)
    if unknown:  # validate everything before touching anything (atomic)
        raise ValueError(
            f"{sorted(unknown)} are not calibratable (one of {_CALIBRATABLE})"
        )
    previous: Dict[str, float] = {}
    for name, value in overrides.items():
        if name == "TILE_OVERHEAD":
            from repro.core import simulator

            previous[name] = simulator.TILE_OVERHEAD
            simulator.TILE_OVERHEAD = float(value)
        else:
            previous[name] = globals()[name]
            globals()[name] = float(value)
    return previous
