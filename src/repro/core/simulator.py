"""Edge-cluster simulator: reproduces the paper's evaluation (Tables IV/V,
Figs. 8-11) from the calibrated cost model + the faithful planner.

Schedules simulated:
  local           single device
  megatron (M-LM) TP, AllReduce x2/layer, connective redundant, equal split
  sp              sequence parallelism, weights replicated, 2 AllGathers/MHA
  galaxy          HMP + heterogeneity/memory-aware planning, sync collectives
  galaxy_overlap  galaxy + tile-based ring overlap (§III-D)

The ring-overlap saving per collective⊗GEMM pair is (D-1)·min(c, g) where c
is the per-hop transfer time and g the per-tile GEMM time — the schedule of
Figs. 6/7 (D GEMM tiles overlapping D-1 hops).

Ragged sequence parallelism: the galaxy schedules score a plan's *uneven*
sequence fractions (``Plan.seq``) — the connective block runs at each
device's own tile, and the ring rotations are costed per step as the
slowest (held tile, outgoing link) pair (``costmodel.t_ring_exchange``),
over per-device ``LinkSpec``s when ``link`` is a sequence.  A real edge
transport sends only each tile's valid rows, so this is the measured-system
view; the padded SPMD emulation is scored by ``simulate_execplan(padded=
True)``, where every device holds (and ships) the straggler's tile.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costmodel, planner
from repro.core.costmodel import DeviceSpec, LinkSpec
from repro.core.execplan import ExecPlan
from repro.core.profiler import AnalyticProfiler

OOM = float("inf")

# Tiling a GEMM into D ring stages lowers per-GEMM efficiency (smaller
# matrices; paper §IV-E observes this "potential underutilization ... due to
# matrix tiling").  ~5% per extra ring stage.
TILE_OVERHEAD = 0.05


@dataclasses.dataclass
class SimResult:
    latency: float                    # end-to-end seconds (inf = OOM)
    per_device_mem: Optional[np.ndarray] = None
    breakdown: Optional[Dict[str, float]] = None

    @property
    def oom(self) -> bool:
        return not np.isfinite(self.latency)


def _embed_bytes(cfg: ModelConfig) -> float:
    return cfg.vocab_size * cfg.d_model * costmodel.BYTES_FP16


def _overlap_layer_time(compute_total: float, comm_total: float, d: int) -> float:
    """Global overlap model for one layer: the D-1 ring hops of all four
    collective⊗GEMM pairs (§III-D) overlap with whatever compute the layer
    has in flight (tile GEMMs, attention core, connective); only the excess
    communication is exposed.  Tiled GEMMs pay a small efficiency penalty."""
    compute_total = compute_total * (1.0 + TILE_OVERHEAD * (d - 1))
    exposed = max(0.0, comm_total - compute_total)
    return compute_total + exposed


def simulate(
    cfg: ModelConfig,
    devices: Sequence[DeviceSpec],
    link: costmodel.Links,
    seq: int,
    schedule: str,
    plan: Optional[planner.Plan] = None,
    context_len: Optional[int] = None,
) -> SimResult:
    """Score one schedule on a simulated edge cluster.

    ``link`` is one LinkSpec for a uniform interconnect or one per device
    (ring order, outgoing).  Non-galaxy schedules move whole tensors every
    step, so heterogeneous links reduce to the bottleneck hop for them.

    ``plan`` (galaxy schedules only) scores an externally supplied partition
    — e.g. one re-expressed from an ``ExecPlan`` — instead of re-running the
    planner, so the simulator and the real executor consume the *same* plan.

    ``context_len`` (galaxy schedules only) prices a *suffix-only* prefill
    after a shared-prefix KV-cache hit: ``seq`` is the uncached suffix the
    layer GEMMs/transport/connective actually run over, while the attention
    core reads keys for the full ``context_len`` positions (the cached
    prefix is gathered from shared pages, not recomputed) — its
    :math:`S'^2` self-attention term rescales to :math:`S' \\cdot K`.
    """
    if plan is not None and schedule not in ("galaxy", "galaxy_overlap"):
        raise ValueError(f"plan= only applies to galaxy schedules, not {schedule!r}")
    if context_len is not None:
        if schedule not in ("galaxy", "galaxy_overlap"):
            raise ValueError(
                f"context_len= only applies to galaxy schedules, not {schedule!r}"
            )
        if context_len < seq:
            raise ValueError(
                f"context_len {context_len} must cover the suffix of {seq} rows"
            )
    d_n = len(devices)
    links = costmodel.as_ring_links(link, d_n)
    link = costmodel.bottleneck_link(links, d_n)
    prof = AnalyticProfiler(cfg, seq)
    p = prof.prof
    l = cfg.num_layers
    act = p["act_bytes"]
    flops = np.array([dev.flops for dev in devices])
    bws = np.array([dev.mem_bw for dev in devices])
    budgets = np.array([dev.memory_budget for dev in devices])

    if schedule == "local":
        dev = devices[0]
        mem = costmodel.model_memory_bytes(cfg)
        if mem > dev.memory_budget:
            return SimResult(OOM, np.array([mem]))
        t = l * (
            (p["mha_flops"] + p["mlp_flops"]) / dev.flops
            + p["con_bytes"] / dev.mem_bw
        )
        return SimResult(t, np.array([mem]))

    if schedule == "megatron":
        # Megatron shards the embedding vocab-parallel as well
        mem = l * (p["m_att"] + p["m_mlp"]) / d_n + _embed_bytes(cfg) / d_n
        per_dev = np.full(d_n, mem)
        if np.any(per_dev > budgets):
            return SimResult(OOM, per_dev)
        t_mha = np.max(p["mha_flops"] / d_n / flops)
        t_mlp = np.max(p["mlp_flops"] / d_n / flops)
        t_con = np.max(p["con_bytes"] / bws)  # redundant on every device
        t_comm = 2 * costmodel.t_allreduce(act, d_n, link)
        t = l * (t_mha + t_mlp + t_con + t_comm)
        return SimResult(t, per_dev, {"comm": l * t_comm, "con": l * t_con})

    if schedule == "sp":
        mem = costmodel.model_memory_bytes(cfg)
        per_dev = np.full(d_n, mem)
        if np.any(per_dev > budgets):
            return SimResult(OOM, per_dev)
        t_comp = np.max((p["mha_flops"] + p["mlp_flops"]) / d_n / flops)
        t_con = np.max(p["con_bytes"] / d_n / bws)
        t_comm = 2 * costmodel.t_allgather(act, d_n, link)  # gather K and V
        t = l * (t_comp + t_con + t_comm)
        return SimResult(t, per_dev, {"comm": l * t_comm, "con": l * t_con})

    if schedule in ("galaxy", "galaxy_overlap"):
        dev_profiles = prof.device_profiles(devices)
        model_profile = prof.model_profile()
        pl = plan if plan is not None else planner.plan(model_profile, dev_profiles)
        if len(pl.mha) != d_n:
            raise ValueError(
                f"plan covers {len(pl.mha)} devices, cluster has {d_n}"
            )

        # fractions vs the *model's* totals, not the plan's sum: identical for
        # planner output (counts sum to the totals) but also correct for
        # padded ExecPlans, where every device executes max(units).
        a_frac = pl.mha / model_profile.num_heads
        b_frac = pl.mlp / model_profile.mlp_columns
        seq_frac = np.asarray(pl.seq, dtype=float)
        per_dev = (
            model_profile.num_layers
            * (model_profile.m_att * a_frac + model_profile.m_mlp * b_frac)
            + _embed_bytes(cfg) / d_n
        )
        if not pl.feasible or np.any(per_dev > budgets):
            return SimResult(OOM, per_dev)
        # split MHA compute: QKV+WO GEMMs (overlappable) vs attention core
        hd, h, kv, dm = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads, cfg.d_model
        qkv_flops = 2 * seq * dm * (h * hd + 2 * kv * hd)
        wo_flops = 2 * seq * (h * hd) * dm
        attn_core = p["mha_flops"] - qkv_flops - wo_flops
        gate = 3 if cfg.activation in ("swiglu", "geglu") else 2
        mlp1_flops = (gate - 1) * 2 * seq * dm * cfg.d_ff
        mlp2_flops = 2 * seq * dm * cfg.d_ff

        if context_len is not None and seq > 0:
            # suffix queries attend over the full context: the S'^2 core
            # becomes S' * K (scores + weighted sum are linear in keys)
            attn_core = attn_core * (context_len / seq)
        t_attn_core = np.max(a_frac * attn_core / flops)
        # connective blocks run at each device's own (possibly uneven)
        # sequence tile, memory-bandwidth-bound
        t_con = np.max(seq_frac * p["con_bytes"] / bws)

        # each ring rotation moves the per-device sequence tiles; a step is
        # gated by the slowest (held tile, outgoing link) pair.  For equal
        # tiles on a uniform link this equals the old closed forms.  Bucketed
        # ragged transport ships bucket-rounded rows (Plan.seq_wire) instead
        # of whatever the compute view holds — compute/connective terms above
        # stay on ``seq``, only the wire is repriced.
        wire_frac = seq_frac if getattr(pl, "seq_wire", None) is None \
            else np.asarray(pl.seq_wire, dtype=float)
        tile_bytes = wire_frac * act
        t_rotation = costmodel.t_ring_exchange(tile_bytes, links)
        pairs = [
            (qkv_flops, a_frac),   # AllGather ⊗ QKV GEMM
            (wo_flops, a_frac),    # WO GEMM ⊗ ReduceScatter
            (mlp1_flops, b_frac),  # AllGather ⊗ GEMM1
            (mlp2_flops, b_frac),  # GEMM2 ⊗ ReduceScatter
        ]
        t_gemms = sum(np.max(fl * fr / flops) for fl, fr in pairs)
        if schedule == "galaxy":
            t_comm = 4 * t_rotation  # 2 AllGathers + 2 ReduceScatters
            t_layer = t_attn_core + t_gemms + t_con + t_comm
        else:
            comm_total = 4 * t_rotation  # hops of all 4 ring pairs
            t_layer = _overlap_layer_time(
                t_attn_core + t_gemms + t_con, comm_total, d_n
            )
        return SimResult(
            l * t_layer,
            per_dev,
            {"con": l * t_con, "attn_core": l * t_attn_core},
        )

    raise ValueError(schedule)


def simulate_execplan(
    eplan: ExecPlan,
    cfg: ModelConfig,
    devices: Sequence[DeviceSpec],
    link: costmodel.Links,
    seq: int,
    *,
    overlap: bool = True,
    padded: bool = False,
    cached_prefix: int = 0,
) -> SimResult:
    """Score the exact plan the executor runs (``core/execplan.ExecPlan``).

    ``cached_prefix`` prices a shared-prefix KV-cache hit
    (``serving/prefix_cache.py``): prefill runs only over the
    ``seq - cached_prefix`` uncached suffix rows (GEMMs, ring transport and
    connective all shrink with the suffix), while the attention core still
    reads the full ``seq`` keys from the shared pages.

    ``padded=False`` scores the planner's assigned workload (paper Eq. 4/5);
    ``padded=True`` scores the SPMD execution view, which depends on the
    plan's ``compute_backend``: under "xla" every device runs
    ``max(units)`` dense units and ships the straggler's ``max(fraction)``
    sequence tile — the price of expressing uneven shards as equal-shaped
    shards; under "pallas" the valid-length kernels shed pad compute, so
    the compute axes score *effective* units (``padded=True`` then differs
    from ``padded=False`` only in the padded-tile transport/connective
    terms — block-rounding residue is ignored).  Comparing the views
    quantifies the padding overhead of a given plan;
    ``benchmarks/microbench.py`` reports them next to the measured wall
    time of the same plan (``execplan_padshed`` for the backend split).
    """
    if eplan.num_devices != len(devices):
        raise ValueError(
            f"plan covers {eplan.num_devices} devices, cluster has {len(devices)}"
        )
    schedule = "galaxy_overlap" if overlap else "galaxy"
    if cached_prefix:
        if not 0 <= cached_prefix < seq:
            raise ValueError(
                f"cached_prefix {cached_prefix} must lie in [0, seq={seq})"
            )
        return simulate(cfg, devices, link, seq - cached_prefix, schedule,
                        plan=eplan.to_planner_plan(padded=padded),
                        context_len=seq)
    return simulate(cfg, devices, link, seq, schedule,
                    plan=eplan.to_planner_plan(padded=padded))


def spec_decode_summary(
    eplan: ExecPlan,
    cfg: ModelConfig,
    devices: Sequence[DeviceSpec],
    link: costmodel.Links,
    *,
    draft_cfg: ModelConfig,
    k: int,
    acceptance: float,
    context_len: int,
) -> Dict[str, float]:
    """Price one speculative round against plain decode on the same plan
    (``serving/spec.py``): the draft model runs ``k`` sequential steps alone
    on the fastest device, then the whole mesh verifies all drafts in one
    ``k+1``-row chunk prefill over the paged cache.

    Every mesh-side step is a suffix-only prefill of the live context:
    plain decode is the 1-row case (``cached_prefix = context - 1``) and
    the verify chunk the ``k+1``-row case — same pricing machinery, so the
    comparison isolates exactly what speculation changes (amortizing the
    per-step transport/connective over ``E`` emitted tokens).  ``speedup``
    is ``E * t_decode / (k * t_draft + t_verify)`` with ``E`` from
    ``costmodel.spec_expected_tokens``; the planner picks ``k`` by maximizing
    it (``choose_spec_k``).
    """
    if context_len <= k + 1:
        raise ValueError(
            f"context_len {context_len} must exceed the k+1={k + 1} verify rows"
        )
    e_tok = costmodel.spec_expected_tokens(acceptance, k)
    t_decode = simulate_execplan(
        eplan, cfg, devices, link, context_len,
        cached_prefix=context_len - 1,
    ).latency
    t_verify = simulate_execplan(
        eplan, cfg, devices, link, context_len,
        cached_prefix=context_len - (k + 1),
    ).latency
    fastest = max(range(len(devices)), key=lambda i: devices[i].flops)
    # the draft runs alone on the fastest device ("local": no transport),
    # so a heterogeneous ring collapses to any single link
    t_draft = simulate(
        draft_cfg, [devices[fastest]],
        costmodel.bottleneck_link(link, len(devices)), 1, "local",
    ).latency
    t_round = k * t_draft + t_verify
    return {
        "k": float(k),
        "acceptance": float(acceptance),
        "expected_tokens": e_tok,
        "t_decode": t_decode,
        "t_draft": t_draft,
        "t_verify": t_verify,
        "time_per_token_plain": t_decode,
        "time_per_token_spec": t_round / e_tok,
        "speedup": e_tok * t_decode / t_round,
    }


def make_step_pricer(
    eplan: ExecPlan,
    cfg: ModelConfig,
    devices: Sequence[DeviceSpec],
    link: costmodel.Links,
    *,
    draft_cfg: Optional[ModelConfig] = None,
    overlap: bool = True,
):
    """Memoized per-step pricer for the serving drift monitor
    (``obs.drift.DriftMonitor``).

    Every serving step the engine executes is priced as the suffix-only
    prefill ``spec_decode_summary`` already uses: a step of ``rows`` new
    positions at live context ``context`` is
    ``simulate_execplan(..., seq=context, cached_prefix=context - rows)`` —
    decode is the 1-row case, a chunked-prefill step the chunk-size-row
    case, a speculative verify chunk the ``k+1``-row case.  The ``kind``
    string only routes ``"draft"`` steps (priced on the fastest device
    alone, needs ``draft_cfg``); all mesh-side kinds share the same math
    and exist so the monitor can histogram them separately.

    Returns ``price(kind, rows=, context=) -> Optional[seconds]`` —
    ``None`` for unpriceable steps (degenerate geometry, unknown draft), so
    the monitor skips them instead of recording garbage.  Results are
    memoized per ``(kind, rows, context)``: serving revisits a small set of
    step shapes thousands of times, and the analytic model is pure.
    """
    if eplan.num_devices != len(devices):
        raise ValueError(
            f"plan covers {eplan.num_devices} devices, cluster has {len(devices)}"
        )
    cache: Dict[tuple, Optional[float]] = {}
    fastest = max(range(len(devices)), key=lambda i: devices[i].flops)

    def price(kind: str, *, rows: int = 1, context: int = 0) -> Optional[float]:
        rows = int(rows)
        context = int(context)
        if rows < 1 or context < rows:
            return None
        key = (kind, rows, context)
        if key not in cache:
            if kind == "draft":
                if draft_cfg is None:
                    cache[key] = None
                else:
                    cache[key] = rows * simulate(
                        draft_cfg, [devices[fastest]],
                        costmodel.bottleneck_link(link, len(devices)),
                        1, "local",
                    ).latency
            else:
                cache[key] = simulate_execplan(
                    eplan, cfg, devices, link, context,
                    overlap=overlap, cached_prefix=context - rows,
                ).latency
        return cache[key]

    return price


def choose_spec_k(
    eplan: ExecPlan,
    cfg: ModelConfig,
    devices: Sequence[DeviceSpec],
    link: costmodel.Links,
    *,
    draft_cfg: ModelConfig,
    acceptance: float,
    context_len: int,
    k_max: int = 8,
) -> Dict[str, float]:
    """Sweep draft depth and return the ``spec_decode_summary`` of the best
    ``k`` (highest modeled speedup; k=1..k_max, bounded by the context).
    Deeper drafts amortize more mesh steps but each extra position lands
    with probability ``acceptance^j``, so the curve peaks and then decays —
    the returned summary is the planner's pick for ``--spec-k``."""
    best: Optional[Dict[str, float]] = None
    for k in range(1, k_max + 1):
        if context_len <= k + 1:
            break
        s = spec_decode_summary(
            eplan, cfg, devices, link, draft_cfg=draft_cfg,
            k=k, acceptance=acceptance, context_len=context_len,
        )
        if best is None or s["speedup"] > best["speedup"]:
            best = s
    if best is None:
        raise ValueError(
            f"context_len {context_len} leaves no room for any draft depth"
        )
    return best


def speedup_table(
    cfg: ModelConfig,
    devices: Sequence[DeviceSpec],
    link: LinkSpec,
    seq: int,
    baselines: Sequence[str] = ("megatron", "sp"),
    galaxy: str = "galaxy_overlap",
) -> Dict[str, object]:
    g = simulate(cfg, devices, link, seq, galaxy)
    out: Dict[str, object] = {"galaxy_s": g.latency}
    for b in baselines:
        r = simulate(cfg, devices, link, seq, b)
        if g.oom:
            out[b] = "GALAXY-OOM"
        elif r.oom:
            out[b] = "OOM"
        else:
            out[b] = r.latency / g.latency
    return out


def weak_scaling(cfg: ModelConfig, device: DeviceSpec, link: LinkSpec,
                 seq_per_device: int, max_devices: int = 4) -> List[float]:
    """Fig. 10: FLOPS scaling efficiency vs linear, single layer."""
    import dataclasses as dc

    cfg1 = dc.replace(cfg, num_layers=1)
    effs = []
    base = None
    for d_n in range(1, max_devices + 1):
        seq = seq_per_device * d_n
        devices = [device] * d_n
        sched = "galaxy_overlap" if d_n > 1 else "local"
        r = simulate(cfg1, devices, link, seq, sched)
        p = costmodel.layer_profile(cfg1, seq)
        flops_rate = (p["mha_flops"] + p["mlp_flops"]) / r.latency
        if base is None:
            base = flops_rate
        effs.append(flops_rate / (base * d_n))
    return effs


def strong_scaling(cfg: ModelConfig, device: DeviceSpec, link: LinkSpec,
                   seq: int, max_devices: int = 4) -> List[float]:
    """Fig. 11: per-layer latency speedup vs local inference."""
    import dataclasses as dc

    cfg1 = dc.replace(cfg, num_layers=1)
    base = simulate(cfg1, [device], link, seq, "local").latency
    out = []
    for d_n in range(1, max_devices + 1):
        sched = "galaxy_overlap" if d_n > 1 else "local"
        r = simulate(cfg1, [device] * d_n, link, seq, sched)
        out.append(base / r.latency)
    return out
