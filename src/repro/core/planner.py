"""Heterogeneity and Memory Aware Workload Planning (paper §III-C, Alg. 1).

Two-step heuristic, faithful to the paper:

1. ``BalancedPartition`` — distribute MHA heads / MLP columns proportional
   to each device's computing capacity V_d (Eq. 6), ignoring memory.
2. ``MemoryAwareBalancing`` — recursively shift the overflowing workload of
   OOM devices to devices with memory headroom, proportional to the free
   devices' capacities; a device that was shifted off is removed from the
   candidate list and the routine recurses.  MLP first (finer granularity),
   then MHA (lines 21-22).  If OOM persists, the cluster cannot host the
   model: planning fails (lines 23-24).

SP (connective blocks) defaults to the paper's equal split (§III-C-2), but
the paper's own premise — bandwidth- *and* compute-heterogeneous edge
clusters — makes that the wrong answer when links are skewed: every ring
step is gated by the slowest (tile, link) pair.  ``sequence_partition``
extends Alg. 1 to the SP axis: per-device sequence tiles start proportional
to compute capacity, then a greedy local search shifts rows to minimize the
straggler connective time plus the ragged-ring exchange time over the given
per-device ``LinkSpec``s (``plan(..., links=...)``).  The executor runs the
resulting uneven tiles as a padded ragged layout (``execplan.SeqLayout``).

On a homogeneous TPU mesh the proportional step degenerates to an equal
split; the planner's memory-aware half then answers "how many chips does
this model need" (see launch/dryrun.py budget checks).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    capacity: float        # V_d = 1 / (L(MHA, full, d) + L(MLP, full, d))  [Eq. 6]
    memory_budget: float   # bytes available for model weights


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Per-layer workload/memory profile (from repro.core.profiler)."""
    name: str
    num_layers: int
    num_heads: int         # MHA partition granularity (paper: head dim)
    mlp_columns: int       # MLP partition granularity (paper: column dim)
    m_att: float           # bytes of one full MHA block's weights
    m_mlp: float           # bytes of one full MLP block's weights


@dataclasses.dataclass
class Plan:
    mha: np.ndarray        # heads per device   (A)
    mlp: np.ndarray        # columns per device (B)
    seq: np.ndarray        # sequence fractions (S) — equal split
    feasible: bool
    reason: str = ""
    # sequence fraction each ring hop ships per device (bucketed ragged
    # transport, ExecPlan.wire_fractions); None -> the hops ship ``seq``
    seq_wire: Optional[np.ndarray] = None

    def memory_per_device(self, model: ModelProfile) -> np.ndarray:
        a = self.mha / max(self.mha.sum(), 1)
        b = self.mlp / max(self.mlp.sum(), 1)
        return model.num_layers * (model.m_att * a + model.m_mlp * b)


def _largest_remainder_round(shares: np.ndarray, total: int) -> np.ndarray:
    """Round non-negative real shares to integers preserving the sum."""
    floor = np.floor(shares).astype(int)
    rem = shares - floor
    short = total - floor.sum()
    order = np.argsort(-rem)
    out = floor.copy()
    for i in range(int(short)):
        out[order[i % len(order)]] += 1
    return out


def balanced_partition(total_units: int, capacities: Sequence[float]) -> np.ndarray:
    """Alg. 1 lines 1-8: workload proportional to computing capacity."""
    v = np.asarray(capacities, dtype=float)
    shares = v / v.sum() * total_units
    return _largest_remainder_round(shares, total_units)


def memory_aware_balancing(
    units: np.ndarray,
    unit_mem: float,
    capacities: Sequence[float],
    budgets: Sequence[float],
    other_mem: np.ndarray,
    active: Optional[List[int]] = None,
) -> Optional[np.ndarray]:
    """Alg. 1 lines 9-19, for one block type T.

    units:     integer workload units currently assigned per device
    unit_mem:  bytes of model weights per workload unit (l * M_T / total_T)
    other_mem: bytes per device already committed by the *other* block type
    active:    list L of candidate devices (shrinks on recursion)

    Returns the rebalanced units, or None if infeasible.
    """
    units = units.copy().astype(int)
    v = np.asarray(capacities, dtype=float)
    budgets = np.asarray(budgets, dtype=float)
    if active is None:
        active = list(range(len(units)))

    def mem(d):
        return units[d] * unit_mem + other_mem[d]

    oom = [d for d in active if mem(d) > budgets[d]]
    if not oom:
        return units
    free = [d for d in active if d not in oom and mem(d) < budgets[d]]
    if not free:
        return None

    next_active = [d for d in active if d not in oom]
    for o in oom:
        headroom_units = int(np.floor((budgets[o] - other_mem[o]) / unit_mem))
        headroom_units = max(headroom_units, 0)
        waiting_shift = units[o] - headroom_units  # overflowing workload
        if waiting_shift <= 0:
            continue
        vf = v[free]
        shares = vf / vf.sum() * waiting_shift
        moved = _largest_remainder_round(shares, waiting_shift)
        for f, mv in zip(free, moved):
            units[f] += int(mv)
        units[o] = headroom_units
    return memory_aware_balancing(units, unit_mem, v, budgets, other_mem, next_active)


def regularize_pad_spread(
    units: np.ndarray,
    capacities: Sequence[float],
    penalty: float,
) -> np.ndarray:
    """Trade straggler latency against pad spread (the ``max(units)`` term).

    SPMD materialization pads every device's shard to ``max(units)``
    (``execplan.ExecPlan``): a capacity-proportional split on a strongly
    skewed cluster therefore buys its balance with pad waste — up to
    ``1 - mean/max`` of executed dense work under the "xla" backend, and
    still block-rounding residue plus padded-tile transport under the
    shedding "pallas" backend.  This post-pass sweeps every candidate
    ``max(units)`` ceiling from the equal split up to the proportional
    split's straggler, waterfilling units proportional to capacity under
    the ceiling, and keeps the assignment minimizing

        cost = max_d(units_d / V_d) / t_balanced  +  penalty * pad_waste

    where ``t_balanced = total / sum(V)`` normalizes the straggler term
    scale-free and ``pad_waste = D * max(units) / total - 1`` is exactly
    the axis' ``padding_waste``.  ``penalty=0`` returns the input
    unchanged (the paper's pure Eq. 4/5 objective); a large penalty
    converges to the equal split (zero padding, megatron-style balance).
    The ceiling sweep is exhaustive over the one scalar that matters
    (``max(units)``), so it cannot strand in the local minima a greedy
    unit-move search hits on skewed capacity vectors.
    """
    units = np.asarray(units).copy().astype(int)
    v = np.asarray(capacities, dtype=float)
    n = len(units)
    total = int(units.sum())
    if penalty <= 0 or n <= 1 or total == 0:
        return units
    t_balanced = total / v.sum()

    def cost(u: np.ndarray) -> float:
        waste = n * u.max() / total - 1.0
        return float(np.max(u / v)) / t_balanced + penalty * waste

    def capped(cap: int) -> Optional[np.ndarray]:
        """Capacity-proportional waterfill with every device <= cap."""
        if cap * n < total:
            return None
        out = np.zeros(n, int)
        active = list(range(n))
        rem = total
        while True:
            assign = balanced_partition(rem, v[active])
            over = [i for i, a in zip(active, assign) if a > cap]
            if not over:
                for i, a in zip(active, assign):
                    out[i] = a
                return out
            for i in over:
                out[i] = cap
                rem -= cap
            active = [i for i in active if i not in over]

    best, best_cost = units, cost(units)
    for cap in range(-(-total // n), int(units.max()) + 1):
        cand = capped(cap)
        if cand is None:
            continue
        c = cost(cand)
        if c < best_cost - 1e-12:
            best, best_cost = cand, c
    return best


def sequence_partition(
    seq_units: int,
    capacities: Sequence[float],
    links=None,
    *,
    unit_bytes: float = 1.0,
    unit_con_time: Optional[Sequence[float]] = None,
    rotations: int = 4,
) -> np.ndarray:
    """Per-device sequence tiles from compute capacity *and* link bandwidth.

    seq_units:     rows of the planning sequence to distribute
    capacities:    V_d (Eq. 6) per device
    links:         per-device outgoing ``costmodel.LinkSpec`` (ring order) or
                   one spec for all; None keeps the capacity-proportional
                   split (the paper's §III-C-2 behavior, generalized from
                   equal to proportional)
    unit_bytes:    activation bytes one sequence row moves per ring hop.
                   With the default proxy ``unit_con_time`` the cost is
                   scale-invariant in it, so the default of 1.0 works; it
                   must carry real bytes once ``unit_con_time`` is given in
                   absolute seconds.  Must be positive when links are given
                   (a zero would silently disable the bandwidth term).
    unit_con_time: seconds of connective work one row costs on each device
                   (con is memory-bandwidth-bound; the profiler supplies
                   ``con_bytes_per_row / mem_bw``).  Defaults to a proxy
                   that scales like the link-byte time and inversely with
                   capacity, so the search cannot degenerate to parking the
                   whole sequence behind the fastest link.

    Minimizes ``max_d(tiles_d * con_d) + rotations * t_ring_exchange(...)``
    — the straggler connective block plus the per-layer ring rotations
    (4 collective⊗GEMM pairs, paper §III-D) — by greedy row moves from a
    capacity-proportional start.  Zero tiles are legal output: a device
    behind a dead-slow link can end up holding no sequence rows while still
    serving its TP head/column shards.
    """
    v = np.asarray(capacities, dtype=float)
    tiles = _largest_remainder_round(v / v.sum() * seq_units, seq_units)
    if links is None or seq_units <= 0 or len(v) <= 1:
        return tiles
    if unit_bytes <= 0:
        raise ValueError(
            "unit_bytes must be positive when links are given — a zero "
            "byte weight makes the cost constant and silently returns the "
            "capacity-proportional split"
        )

    from repro.core import costmodel  # here to keep planner import-light

    ring = costmodel.as_ring_links(links, len(v))
    if unit_con_time is None:
        bw = np.mean([l.bandwidth for l in ring])
        con = (unit_bytes / max(bw, 1e-30)) * (v.mean() / v)
    else:
        con = np.asarray(unit_con_time, dtype=float)

    def cost(t: np.ndarray) -> float:
        t_con = float(np.max(t * con))
        comm = costmodel.t_ring_exchange(t * unit_bytes, ring)
        return t_con + rotations * comm

    best = tiles.astype(int)
    best_cost = cost(best)
    n = len(best)
    step = max(1, seq_units // (4 * n))
    while True:
        improved = False
        for src in range(n):
            if best[src] < step:
                continue
            for dst in range(n):
                if dst == src:
                    continue
                cand = best.copy()
                cand[src] -= step
                cand[dst] += step
                c = cost(cand)
                if c < best_cost - 1e-15:
                    best, best_cost, improved = cand, c, True
        if not improved:
            if step == 1:
                break
            step = max(1, step // 2)
    return best


def plan(
    model: ModelProfile,
    devices: Sequence[DeviceProfile],
    links=None,
    *,
    seq_units: int = 0,
    unit_bytes: float = 1.0,
    unit_con_time: Optional[Sequence[float]] = None,
    pad_penalty: float = 0.0,
) -> Plan:
    """Full Algorithm 1 (+ the ragged-SP extension when ``links`` is given).

    Without ``links`` the SP axis stays the equal split of §III-C-2.  With
    per-device links, ``sequence_partition`` solves uneven sequence tiles
    over ``seq_units`` rows (the planning sequence length) and ``Plan.seq``
    carries the resulting fractions.

    ``pad_penalty`` co-optimizes balance against residual pad waste: the
    balanced head/column partitions are post-passed by
    :func:`regularize_pad_spread` before memory-aware balancing, trading a
    little straggler latency for a smaller ``max(units)`` spread (what the
    SPMD executor pads — and even the shedding pallas backend still ships —
    on every device).
    """
    v = [d.capacity for d in devices]
    budgets = [d.memory_budget for d in devices]
    n = len(devices)

    a = balanced_partition(model.num_heads, v)        # line 7
    b = balanced_partition(model.mlp_columns, v)      # line 8
    if pad_penalty > 0:
        a = regularize_pad_spread(a, v, pad_penalty)
        b = regularize_pad_spread(b, v, pad_penalty)
    if links is None:
        seq = np.full(n, 1.0 / n)                     # §III-C-2: equal SP split
    else:
        units = seq_units or 32 * n
        tiles = sequence_partition(
            units, v, links, unit_bytes=unit_bytes,
            unit_con_time=unit_con_time,
        )
        seq = tiles.astype(float) / units

    att_unit = model.num_layers * model.m_att / model.num_heads
    mlp_unit = model.num_layers * model.m_mlp / model.mlp_columns

    # line 21: rebalance MLP first (finer granularity), MHA memory fixed
    b2 = memory_aware_balancing(b, mlp_unit, v, budgets, other_mem=a * att_unit)
    if b2 is None:
        return Plan(a, b, seq, False, "MLP rebalancing infeasible")
    # line 22: rebalance MHA with the final MLP memory committed
    a2 = memory_aware_balancing(a, att_unit, v, budgets, other_mem=b2 * mlp_unit)
    if a2 is None:
        return Plan(a, b2, seq, False, "MHA rebalancing infeasible")

    if pad_penalty > 0:
        # memory balancing can re-raise max(units) (it shifts overflow onto
        # devices with headroom, cap-free); re-regularize and keep the
        # result only if it still fits every budget
        a3 = regularize_pad_spread(a2, v, pad_penalty)
        b3 = regularize_pad_spread(b2, v, pad_penalty)
        if not np.any(a3 * att_unit + b3 * mlp_unit > np.asarray(budgets)):
            a2, b2 = a3, b3

    # lines 23-24: final feasibility check
    total = a2 * att_unit + b2 * mlp_unit
    if np.any(total > np.asarray(budgets)):
        return Plan(a2, b2, seq, False, "OOM persists after redistribution")
    return Plan(a2, b2, seq, True)


def block_latency(units: int, total_units: int, total_flops: float, capacity: float) -> float:
    """L(T, C_d, d): execution latency of a block shard on one device."""
    return (units / total_units) * total_flops / capacity


def plan_latency(
    plan_: Plan,
    model: ModelProfile,
    devices: Sequence[DeviceProfile],
    mha_flops: float,
    mlp_flops: float,
    con_time_full: float,
) -> float:
    """Eq. 4/5 objective: per-layer straggler latency under a plan.
    capacity here is normalized so total_flops/capacity = seconds."""
    t_mha = max(
        block_latency(int(a), model.num_heads, mha_flops, d.capacity)
        for a, d in zip(plan_.mha, devices)
    )
    t_mlp = max(
        block_latency(int(b), model.mlp_columns, mlp_flops, d.capacity)
        for b, d in zip(plan_.mlp, devices)
    )
    t_con = con_time_full * float(np.max(plan_.seq))
    return t_mha + t_mlp + t_con
