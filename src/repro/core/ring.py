"""Tile-based communication/computation overlap (paper §III-D), TPU-native.

The paper decomposes the GEMM adjacent to each collective into row tiles and
pipelines a D-step ring so each hop's transfer overlaps the previous tile's
GEMM.  On TPU we express the same schedule with ``jax.lax.ppermute`` inside
``shard_map``: the loop is unrolled (D is a static mesh-axis size), giving
XLA a dependence structure where ppermute r+1 is independent of GEMM r —
exactly what the latency-hiding scheduler overlaps on real hardware.

Two primitives, mirroring the paper's Fig. 6 / Fig. 7:

* ``ring_allgather_matmul``   — AllGather ⊗ GEMM1 (entering a TP block)
* ``matmul_ring_reducescatter`` — GEMM2 ⊗ ReduceScatter (exiting a TP block)

Both take an explicit ``tile_size`` (the per-device sequence tile, i.e. the
``ExecPlan.seq_tile``) instead of assuming an implicit equal split of the
global sequence.  Shape mismatches raise ``ValueError`` at trace time — a
Python ``assert`` would vanish under ``-O`` and produce an opaque XLA shape
error for jit users.

Both are bitwise-consistent with the unoverlapped collective versions up to
floating-point summation order (the ring fixes a deterministic order).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _perm(axis_size: int, shift: int = 1):
    return [(i, (i + shift) % axis_size) for i in range(axis_size)]


def _axis_size(axis_name: str) -> int:
    # jax.lax.axis_size is missing from older jax; psum of a literal 1
    # constant-folds to the (static) axis size on every version.
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def ring_allgather_matmul(x_local, w_local, axis_name: str,
                          *, tile_size: Optional[int] = None):
    """Overlapped computation of ``all_gather(x, seq) @ w_local``.

    x_local: (B, S_loc, d)   — this device's sequence tile (paper's H_i)
    w_local: (d, F_loc)      — this device's column shard (paper's W_i^D)
    tile_size: sequence rows per ring tile; defaults to ``S_loc`` and must
               equal it (every device contributes one tile per ring step).
    returns: (B, D*tile_size, F_loc) — full-sequence activation, local columns.

    Step r computes the GEMM for the tile received r hops ago while the next
    tile is in flight; the final step does no communication (paper §III-D-1).
    """
    d = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    b, s_loc, _ = x_local.shape
    if tile_size is None:
        tile_size = s_loc
    elif tile_size != s_loc:
        raise ValueError(
            f"local sequence tile is {s_loc} rows but tile_size={tile_size}; "
            "the ring AllGather moves whole local tiles"
        )
    f_loc = w_local.shape[1]

    out = jnp.zeros((b, d * tile_size, f_loc), x_local.dtype)
    tile = x_local
    for r in range(d):
        src = jnp.mod(idx - r, d)  # owner of the tile we hold at step r
        part = jnp.einsum("bsd,df->bsf", tile, w_local)
        out = jax.lax.dynamic_update_slice(out, part, (0, src * tile_size, 0))
        if r != d - 1:
            # send current tile forward; receive the next from the ring
            tile = jax.lax.ppermute(tile, axis_name, _perm(d))
    return out


def matmul_ring_reducescatter(h_local, w_local, axis_name: str,
                              *, tile_size: Optional[int] = None):
    """Overlapped computation of ``psum_scatter(h_local @ w_local, seq)``.

    h_local: (B, S, F_loc)   — full sequence, this device's column shard (E_i)
    w_local: (F_loc, d)      — row shard of the second GEMM (W_i^E)
    tile_size: rows of the output tile each device ends up owning; defaults
               to ``S // D`` and must satisfy ``D * tile_size == S``.
    returns: (B, tile_size, d) — this device's sequence tile of the summed
             output.

    Schedule (paper §III-D-2): at step r device i GEMMs its tile
    (i - r + D - 1) mod D and adds the partial sum arriving from its
    predecessor, which processed the same tile one step earlier.  After D
    steps device i owns the fully-reduced tile i.
    """
    d = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    b, s, _ = h_local.shape
    if tile_size is None:
        if s % d:
            raise ValueError(
                f"sequence {s} does not divide over a ring of {d} devices; "
                "pass tile_size (or pad the sequence to a multiple of the mesh)"
            )
        tile_size = s // d
    elif d * tile_size != s:
        raise ValueError(
            f"tile_size={tile_size} x {d} devices != sequence {s}; the ring "
            "ReduceScatter consumes exactly one tile per device per step"
        )

    acc = None
    for r in range(d):
        t = jnp.mod(idx - r + d - 1, d)  # tile index to process this step
        tile = jax.lax.dynamic_slice(
            h_local, (0, t * tile_size, 0), (b, tile_size, h_local.shape[2])
        )
        part = jnp.einsum("bsf,fd->bsd", tile, w_local)
        if acc is None:
            acc = part
        else:
            acc = part + jax.lax.ppermute(acc, axis_name, _perm(d))
    return acc


# --- unoverlapped references (the paper's "sync" baseline schedule) -----------

def sync_allgather_matmul(x_local, w_local, axis_name: str,
                          *, tile_size: Optional[int] = None):
    if tile_size is not None and tile_size != x_local.shape[1]:
        raise ValueError(
            f"local sequence tile is {x_local.shape[1]} rows but "
            f"tile_size={tile_size}"
        )
    xg = jax.lax.all_gather(x_local, axis_name, axis=1, tiled=True)
    return jnp.einsum("bsd,df->bsf", xg, w_local)


def sync_matmul_reducescatter(h_local, w_local, axis_name: str,
                              *, tile_size: Optional[int] = None):
    d = _axis_size(axis_name)
    s = h_local.shape[1]
    if (tile_size is None and s % d) or (
            tile_size is not None and d * tile_size != s):
        raise ValueError(
            f"sequence {s} does not split into {d} equal scatter tiles"
            + (f" of {tile_size}" if tile_size is not None else "")
        )
    out = jnp.einsum("bsf,fd->bsd", h_local, w_local)
    return jax.lax.psum_scatter(out, axis_name, scatter_dimension=1, tiled=True)
