"""Tile-based communication/computation overlap (paper §III-D), TPU-native.

The paper decomposes the GEMM adjacent to each collective into row tiles and
pipelines a D-step ring so each hop's transfer overlaps the previous tile's
GEMM.  On TPU we express the same schedule with ``jax.lax.ppermute`` inside
``shard_map``: the loop is unrolled (D is a static mesh-axis size), giving
XLA a dependence structure where ppermute r+1 is independent of GEMM r —
exactly what the latency-hiding scheduler overlaps on real hardware.

Two primitives, mirroring the paper's Fig. 6 / Fig. 7:

* ``ring_allgather_matmul``   — AllGather ⊗ GEMM1 (entering a TP block)
* ``matmul_ring_reducescatter`` — GEMM2 ⊗ ReduceScatter (exiting a TP block)

Both take an explicit ``tile_size`` (the per-device sequence tile, i.e. the
``ExecPlan.seq_tile``) instead of assuming an implicit equal split of the
global sequence.  Shape mismatches raise ``ValueError`` at trace time — a
Python ``assert`` would vanish under ``-O`` and produce an opaque XLA shape
error for jit users.

Ragged sequence parallelism (uneven per-device tiles) rides the same
schedule through *padded* tiles with per-step valid-length masking:

* every device's shard is padded to ``tile_size = max(tiles)`` rows and the
  ring ppermutes whole padded tiles (SPMD shapes must stay equal — a real
  point-to-point deployment would send only the valid rows, which is what
  ``costmodel.t_ring_exchange`` scores);
* ``valid_sizes[d]`` names how many rows of device ``d``'s tile are real,
  in ring order.  At each step the receiver zeroes the pad rows of the tile
  it currently holds before the GEMM, so pad rows contribute exactly zero
  to every output and the math stays exact — including zero-sized tiles
  (a device behind a dead-slow link may own no sequence rows at all).

The global padded layout (which padded row holds which real position) is
owned by ``execplan.SeqLayout``; this module only needs the per-device
valid counts.

Pluggable per-tile compute (``ExecPlan.compute_backend``): each primitive
takes an optional ``gemm(tile, w, valid_rows)`` callback.  Without one the
per-step GEMM is the masked einsum above (pad rows zeroed, then a dense
dot — the "xla" oracle).  With one — the "pallas" path binds
``kernels.ops.gemm`` with this device's valid column/contraction counts —
the valid-length kernel owns the row masking itself (its epilogue zeroes
pad rows exactly), so the pre-mask is skipped and pad *blocks* are never
computed at all.

All four functions are bitwise-consistent with each other up to
floating-point summation order (the ring fixes a deterministic order).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# per-tile GEMM hook: (x_tile (B,S,d), w (d,F), valid_rows scalar | None)
# -> (B,S,F) with pad rows (rows >= valid_rows) exactly zero
TileGemm = Callable[..., jnp.ndarray]


def _perm(axis_size: int, shift: int = 1):
    return [(i, (i + shift) % axis_size) for i in range(axis_size)]


def _check_valid_sizes(valid_sizes: Optional[Sequence[int]], d: int,
                       tile_size: int) -> Optional[np.ndarray]:
    """Normalize the per-device valid row counts of a ragged ring.

    Returns None when masking is a no-op (no ragged info, or every tile is
    fully valid) so the dense path keeps its exact pre-ragged XLA graph.
    """
    if valid_sizes is None:
        return None
    vs = np.asarray(valid_sizes, int)
    if vs.shape != (d,):
        raise ValueError(
            f"valid_sizes covers {vs.size} devices but the ring has {d}"
        )
    if vs.min() < 0 or vs.max() > tile_size:
        raise ValueError(
            f"valid_sizes {vs.tolist()} must lie in [0, tile_size={tile_size}]"
        )
    if (vs == tile_size).all():
        return None
    return vs


def _axis_size(axis_name: str) -> int:
    # jax.lax.axis_size is missing from older jax; psum of a literal 1
    # constant-folds to the (static) axis size on every version.
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def ring_allgather_matmul(x_local, w_local, axis_name: str,
                          *, tile_size: Optional[int] = None,
                          valid_sizes: Optional[Sequence[int]] = None,
                          gemm: Optional[TileGemm] = None):
    """Overlapped computation of ``all_gather(x, seq) @ w_local``.

    x_local: (B, S_loc, d)   — this device's sequence tile (paper's H_i)
    w_local: (d, F_loc)      — this device's column shard (paper's W_i^D)
    tile_size: sequence rows per ring tile; defaults to ``S_loc`` and must
               equal it (every device contributes one tile per ring step).
    valid_sizes: ragged SP — real rows of each device's padded tile, in
               ring order; pad rows of every received tile are zeroed
               before the GEMM so the output's pad rows are exactly zero.
    returns: (B, D*tile_size, F_loc) — full-sequence activation (padded
             layout when ragged), local columns.

    Step r computes the GEMM for the tile received r hops ago while the next
    tile is in flight; the final step does no communication (paper §III-D-1).
    """
    d = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    b, s_loc, _ = x_local.shape
    if tile_size is None:
        tile_size = s_loc
    elif tile_size != s_loc:
        raise ValueError(
            f"local sequence tile is {s_loc} rows but tile_size={tile_size}; "
            "the ring AllGather moves whole local tiles"
        )
    vs = _check_valid_sizes(valid_sizes, d, tile_size)
    f_loc = w_local.shape[1]

    out = jnp.zeros((b, d * tile_size, f_loc), x_local.dtype)
    tile = x_local
    for r in range(d):
        src = jnp.mod(idx - r, d)  # owner of the tile we hold at step r
        if gemm is not None:
            # valid-length kernel: masks pad rows itself and skips pad blocks
            vrows = None if vs is None else jnp.asarray(vs)[src]
            part = gemm(tile, w_local, vrows)
        else:
            if vs is not None:
                row_ok = jnp.arange(tile_size) < jnp.asarray(vs)[src]
                gemm_in = jnp.where(row_ok[None, :, None], tile, 0)
            else:
                gemm_in = tile
            part = jnp.einsum("bsd,df->bsf", gemm_in, w_local)
        out = jax.lax.dynamic_update_slice(out, part, (0, src * tile_size, 0))
        if r != d - 1:
            # send current tile forward; receive the next from the ring
            tile = jax.lax.ppermute(tile, axis_name, _perm(d))
    return out


def matmul_ring_reducescatter(h_local, w_local, axis_name: str,
                              *, tile_size: Optional[int] = None,
                              valid_sizes: Optional[Sequence[int]] = None,
                              gemm: Optional[TileGemm] = None):
    """Overlapped computation of ``psum_scatter(h_local @ w_local, seq)``.

    h_local: (B, S, F_loc)   — full sequence, this device's column shard (E_i)
    w_local: (F_loc, d)      — row shard of the second GEMM (W_i^E)
    tile_size: rows of the output tile each device ends up owning; defaults
               to ``S // D`` and must satisfy ``D * tile_size == S``.
    valid_sizes: ragged SP — real rows of each device's output tile; pad
               rows are zeroed going into every per-step GEMM, so each
               device's pad rows come back exactly zero.
    returns: (B, tile_size, d) — this device's sequence tile of the summed
             output.

    Schedule (paper §III-D-2): at step r device i GEMMs its tile
    (i - r + D - 1) mod D and adds the partial sum arriving from its
    predecessor, which processed the same tile one step earlier.  After D
    steps device i owns the fully-reduced tile i.
    """
    d = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    b, s, _ = h_local.shape
    if tile_size is None:
        if s % d:
            raise ValueError(
                f"sequence {s} does not divide over a ring of {d} devices; "
                "pass tile_size, or run a ragged layout (ExecPlan.seq_layout "
                "-> tile_size=pad_tile, valid_sizes=tiles)"
            )
        tile_size = s // d
    elif d * tile_size != s:
        raise ValueError(
            f"tile_size={tile_size} x {d} devices != sequence {s}; the ring "
            "ReduceScatter consumes exactly one tile per device per step"
        )
    vs = _check_valid_sizes(valid_sizes, d, tile_size)

    acc = None
    for r in range(d):
        t = jnp.mod(idx - r + d - 1, d)  # tile index to process this step
        tile = jax.lax.dynamic_slice(
            h_local, (0, t * tile_size, 0), (b, tile_size, h_local.shape[2])
        )
        if gemm is not None:
            part = gemm(tile, w_local, None if vs is None else jnp.asarray(vs)[t])
        else:
            if vs is not None:
                row_ok = jnp.arange(tile_size) < jnp.asarray(vs)[t]
                tile = jnp.where(row_ok[None, :, None], tile, 0)
            part = jnp.einsum("bsf,fd->bsd", tile, w_local)
        if acc is None:
            acc = part
        else:
            acc = part + jax.lax.ppermute(acc, axis_name, _perm(d))
    return acc


# --- unoverlapped references (the paper's "sync" baseline schedule) -----------

def _global_valid_mask(vs: np.ndarray, tile_size: int) -> np.ndarray:
    """(D*tile_size,) bool: valid rows of the concatenated padded layout."""
    return np.concatenate([np.arange(tile_size) < v for v in vs])


def sync_allgather_matmul(x_local, w_local, axis_name: str,
                          *, tile_size: Optional[int] = None,
                          valid_sizes: Optional[Sequence[int]] = None,
                          gemm: Optional[TileGemm] = None):
    if tile_size is not None and tile_size != x_local.shape[1]:
        raise ValueError(
            f"local sequence tile is {x_local.shape[1]} rows but "
            f"tile_size={tile_size}"
        )
    d = _axis_size(axis_name)
    vs = _check_valid_sizes(valid_sizes, d, x_local.shape[1])
    xg = jax.lax.all_gather(x_local, axis_name, axis=1, tiled=True)
    if vs is not None:
        # the gathered sequence mixes per-tile valid counts, which the
        # prefix-valid kernel cannot express: mask rows here either way
        # (a shedding gemm still skips pad column/contraction blocks)
        mask = _global_valid_mask(vs, x_local.shape[1])
        xg = jnp.where(jnp.asarray(mask)[None, :, None], xg, 0)
    if gemm is not None:
        return gemm(xg, w_local, None)
    return jnp.einsum("bsd,df->bsf", xg, w_local)


def sync_matmul_reducescatter(h_local, w_local, axis_name: str,
                              *, tile_size: Optional[int] = None,
                              valid_sizes: Optional[Sequence[int]] = None,
                              gemm: Optional[TileGemm] = None):
    d = _axis_size(axis_name)
    s = h_local.shape[1]
    if (tile_size is None and s % d) or (
            tile_size is not None and d * tile_size != s):
        raise ValueError(
            f"sequence {s} does not split into {d} equal scatter tiles"
            + (f" of {tile_size}" if tile_size is not None else "")
        )
    vs = _check_valid_sizes(valid_sizes, d, s // d)
    if vs is not None:
        mask = _global_valid_mask(vs, s // d)
        h_local = jnp.where(jnp.asarray(mask)[None, :, None], h_local, 0)
    if gemm is not None:
        out = gemm(h_local, w_local, None)
    else:
        out = jnp.einsum("bsf,fd->bsd", h_local, w_local)
    return jax.lax.psum_scatter(out, axis_name, scatter_dimension=1, tiled=True)
