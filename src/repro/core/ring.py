"""Ring schedules: tile-granular compute/communication overlap (paper §III-D).

The paper decomposes the GEMM adjacent to each collective into row tiles and
pipelines a D-step ring so each hop's transfer overlaps the previous tile's
GEMM.  This module owns that program through one object:

* ``TileSpec``    — one ring tile: which device owns it, how many of its
  rows are real (``valid``), and how many rows each hop actually ships
  (``bucket``).
* ``RingSchedule`` — the full per-step program: the tiles in ring order, the
  SPMD buffer size (``pad_tile``), the transport mode, whether the schedule
  is double-buffered, and the per-tile compute hook (``gemm``).  For step
  ``r``, device ``i`` holds the tile owned by ``schedule.source(i, r)``, in
  buffer slot ``schedule.buffer_slot(r)``, and its outgoing link carries
  ``bucket[source(i, r)]`` rows on the next hop.

Two overlapped primitives, mirroring the paper's Fig. 6 / Fig. 7, plus two
unoverlapped ``sync_*`` references, all take ``schedule=``:

* ``ring_allgather_matmul``     — AllGather ⊗ GEMM1 (entering a TP block)
* ``matmul_ring_reducescatter`` — GEMM2 ⊗ ReduceScatter (exiting a TP block)

Ragged sequence parallelism (uneven per-device tiles) rides the same ring
through *padded* tiles with per-step valid-length masking: every device's
shard is padded to ``pad_tile = max(tiles)`` rows, and at each step the
receiver zeroes the pad rows of the tile it currently holds before the GEMM,
so pad rows contribute exactly zero to every output — including zero-sized
tiles.  On top of that layout the schedule adds two transport upgrades:

* **Bucketed ragged transport** (``transport="bucketed"``): tile row counts
  are rounded up to a small static set of bucket sizes (``BUCKETS_PER_TILE``
  buckets per tile by default), and each hop ships each tile as a stack of
  row *segments* — one partial ``ppermute`` per distinct bucket boundary,
  with only the devices whose held tile reaches that boundary participating.
  Receivers of an omitted segment get exact zeros, which is precisely what
  those pad rows must hold, so the math is unchanged while each hop moves
  ~``bucket`` rows instead of ``max(tiles)`` rows.  The segment membership
  is solved ahead of trace time (it only depends on the static hop index),
  so the wire program is fully static.
* **Double-buffered overlap** (``double_buffer=True``): hop ``r``'s transfer
  is issued *before* step ``r``'s GEMM consumes the buffer it frees, and the
  two are pinned on opposite sides of an ``optimization_barrier`` — transfer
  genuinely hides behind compute instead of relying on XLA's latency-hiding
  scheduler to reorder it.  The dataflow (and hence the floating-point
  summation order) is identical to the single-buffered schedule.

The global padded layout (which padded row holds which real position) is
owned by ``execplan.SeqLayout``; ``ExecPlan.ring_schedule()`` builds the
matching ``RingSchedule`` from a plan's sequence shares, and
``costmodel.t_ring_exchange`` prices exactly the bucketed bytes the schedule
ships (via ``Plan.seq_wire``).

Pluggable per-tile compute (``ExecPlan.compute_backend``): the schedule's
``gemm(tile, w, valid_rows)`` hook replaces the masked einsum.  Without one
the per-step GEMM is the masked dense dot (the "xla" oracle); with one — the
"pallas" path binds ``kernels.ops.gemm`` with this device's valid counts —
the valid-length kernel owns the row masking itself, so pad *blocks* are
never computed at all.

Shape mismatches raise ``ValueError`` at trace time — a Python ``assert``
would vanish under ``-O`` and produce an opaque XLA shape error for jit
users.  All four functions are bitwise-consistent with each other up to
floating-point summation order (the ring fixes a deterministic order, which
bucketing and double buffering both preserve exactly).

The schedule is the only configuration surface: the pre-schedule keywords
(``tile_size=``, ``valid_sizes=``, ``gemm=``) were deprecated shims for one
release and have been removed — build a ``RingSchedule`` (``.dense`` /
``.ragged`` / ``.with_gemm``) instead.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# per-tile GEMM hook: (x_tile (B,S,d), w (d,F), valid_rows scalar | None)
# -> (B,S,F) with pad rows (rows >= valid_rows) exactly zero
TileGemm = Callable[..., jnp.ndarray]

#: supported wire formats for ragged tiles
RING_TRANSPORTS = ("padded", "bucketed")

#: default bucket granularity: tiles round up to pad_tile/4 row multiples,
#: so a hop decomposes into at most 4 segment ppermutes
BUCKETS_PER_TILE = 4


def _perm(axis_size: int, shift: int = 1):
    return [(i, (i + shift) % axis_size) for i in range(axis_size)]


def _axis_size(axis_name: str) -> int:
    # jax.lax.axis_size is missing from older jax; psum of a literal 1
    # constant-folds to the (static) axis size on every version.
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def _hop_permute(seg, axis_name: str, pairs, d: int):
    """Rotate ``seg`` one ring position for the devices named in ``pairs``.

    ``pairs`` must be a subset of the +1 rotation.  Devices not named as a
    destination receive exact zeros (lax.ppermute's partial-permutation
    semantics) — under vmap-emulated rings, whose ppermute batching rule
    insists on a full permutation, the same semantics are encoded as a
    sender-side gate followed by a full rotation.
    """
    if len(pairs) == d:
        return jax.lax.ppermute(seg, axis_name, pairs)
    try:
        return jax.lax.ppermute(seg, axis_name, pairs)
    except Exception:
        ships = np.zeros(d, dtype=bool)
        ships[[src for src, _ in pairs]] = True
        idx = jax.lax.axis_index(axis_name)
        gated = jnp.where(jnp.asarray(ships)[idx], seg, jnp.zeros_like(seg))
        return jax.lax.ppermute(gated, axis_name, _perm(d))


@dataclasses.dataclass(frozen=True)
class TileSpec:
    """One ring tile: its owner, real rows, and on-wire rows.

    ``valid`` rows of the padded tile hold real sequence positions;
    ``bucket`` (``valid <= bucket <= pad_tile``) is how many rows each ring
    hop ships for this tile — ``pad_tile`` under padded transport, the
    bucket-rounded count under bucketed transport.
    """

    owner: int
    valid: int
    bucket: int


@dataclasses.dataclass(frozen=True)
class RingSchedule:
    """The per-step program of a D-device ring (see module docstring).

    ``tiles`` are in ring order (``tiles[i].owner == i``); ``pad_tile`` is
    the common SPMD buffer size every tile is padded to.  ``gemm`` is the
    optional per-tile compute hook threaded to every step.
    """

    tiles: Tuple[TileSpec, ...]
    pad_tile: int
    transport: str = "padded"
    double_buffer: bool = False
    gemm: Optional[TileGemm] = None

    def __post_init__(self):
        object.__setattr__(self, "tiles", tuple(self.tiles))
        if not self.tiles:
            raise ValueError("RingSchedule needs at least one tile")
        if self.pad_tile < 1:
            raise ValueError(f"pad_tile must be >= 1, got {self.pad_tile}")
        if self.transport not in RING_TRANSPORTS:
            raise ValueError(
                f"unknown ring transport {self.transport!r}; "
                f"expected one of {RING_TRANSPORTS}"
            )
        for i, t in enumerate(self.tiles):
            if t.owner != i:
                raise ValueError(
                    f"tiles must be in ring order: tiles[{i}].owner == {t.owner}"
                )
            if not (0 <= t.valid <= t.bucket <= self.pad_tile):
                raise ValueError(
                    f"tile {i}: need 0 <= valid <= bucket <= pad_tile, got "
                    f"valid={t.valid} bucket={t.bucket} pad_tile={self.pad_tile}"
                )

    # --- constructors ---------------------------------------------------------

    @classmethod
    def ragged(cls, tiles: Sequence[int], *, pad_tile: Optional[int] = None,
               transport: str = "padded", bucket_grain: Optional[int] = None,
               double_buffer: bool = False,
               gemm: Optional[TileGemm] = None) -> "RingSchedule":
        """Schedule for per-device ``tiles`` valid row counts, in ring order.

        Under bucketed transport each tile's wire size rounds up to a
        multiple of ``bucket_grain`` (default ``ceil(pad_tile /
        BUCKETS_PER_TILE)``), clipped to ``pad_tile``; zero tiles ship
        nothing.
        """
        valid = [int(t) for t in tiles]
        if pad_tile is None:
            pad_tile = max(valid) if valid else 0
        pad_tile = int(pad_tile)
        if transport == "bucketed":
            grain = int(bucket_grain) if bucket_grain else max(
                1, -(-pad_tile // BUCKETS_PER_TILE))
            buckets = [min(pad_tile, -(-v // grain) * grain) for v in valid]
        else:
            buckets = [pad_tile] * len(valid)
        specs = tuple(
            TileSpec(owner=i, valid=v, bucket=b)
            for i, (v, b) in enumerate(zip(valid, buckets))
        )
        return cls(specs, pad_tile=pad_tile, transport=transport,
                   double_buffer=double_buffer, gemm=gemm)

    @classmethod
    def dense(cls, num_devices: int, tile_size: int, *,
              transport: str = "padded", double_buffer: bool = False,
              gemm: Optional[TileGemm] = None) -> "RingSchedule":
        """Equal fully-valid tiles — the classic even-split ring."""
        return cls.ragged([tile_size] * num_devices, pad_tile=tile_size,
                          transport=transport, double_buffer=double_buffer,
                          gemm=gemm)

    def with_gemm(self, gemm: Optional[TileGemm]) -> "RingSchedule":
        """The same wire program with a different per-tile compute hook."""
        return dataclasses.replace(self, gemm=gemm)

    # --- static geometry ------------------------------------------------------

    @property
    def num_devices(self) -> int:
        return len(self.tiles)

    @property
    def valid_sizes(self) -> np.ndarray:
        return np.asarray([t.valid for t in self.tiles], int)

    @property
    def buckets(self) -> np.ndarray:
        return np.asarray([t.bucket for t in self.tiles], int)

    @property
    def is_masked(self) -> bool:
        """Whether any tile carries pad rows (per-step masking needed)."""
        return bool((self.valid_sizes < self.pad_tile).any())

    @property
    def is_bucketed(self) -> bool:
        """Whether any hop ships fewer than ``pad_tile`` rows."""
        return self.transport == "bucketed" and bool(
            (self.buckets < self.pad_tile).any())

    @property
    def segment_bounds(self) -> Tuple[int, ...]:
        """Row boundaries of the per-hop wire segments: (0, b_1, .., b_max)."""
        return (0, *sorted({t.bucket for t in self.tiles if t.bucket > 0}))

    def source(self, device, step: int):
        """Owner of the tile ``device`` holds at ring step ``step``."""
        return (device - step) % self.num_devices

    def buffer_slot(self, step: int) -> int:
        """Which of the two tile buffers step ``step`` computes from."""
        return step % 2 if self.double_buffer else 0

    # --- wire accounting (what the hops actually ship) ------------------------

    def hop_rows(self, hop: int) -> np.ndarray:
        """Rows device ``i`` ships on hop ``hop`` (it holds tile source(i, hop))."""
        d = self.num_devices
        return np.asarray(
            [self.tiles[(i - hop) % d].bucket for i in range(d)], int)

    def total_wire_rows(self) -> int:
        """Tile rows shipped across one full rotation (d-1 hops, all links)."""
        return (self.num_devices - 1) * int(self.buckets.sum())

    def padded_wire_rows(self) -> int:
        """What one rotation would ship under padded transport."""
        return (self.num_devices - 1) * self.num_devices * self.pad_tile

    def wire_fraction(self) -> float:
        """Shipped rows as a fraction of the padded-transport rotation."""
        padded = self.padded_wire_rows()
        return self.total_wire_rows() / padded if padded else 1.0

    # --- the hop itself -------------------------------------------------------

    def ship(self, tile, axis_name: str, hop: int):
        """One ring hop (device i -> i+1) of the currently-held tiles.

        Under padded transport this is a single full-tile ``ppermute``.
        Under bucketed transport the tile is shipped as row segments between
        consecutive bucket boundaries; each segment's ppermute names only
        the devices whose held tile reaches that boundary, so receivers of
        an omitted segment get exact zeros (their pad rows).
        """
        d = self.num_devices
        if not self.is_bucketed:
            return jax.lax.ppermute(tile, axis_name, _perm(d))
        buckets = self.buckets
        bounds = self.segment_bounds
        parts = []
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            pairs = [(i, (i + 1) % d) for i in range(d)
                     if buckets[(i - hop) % d] >= hi]
            seg = jax.lax.slice_in_dim(tile, lo, hi, axis=1)
            parts.append(_hop_permute(seg, axis_name, pairs, d))
        if bounds[-1] < self.pad_tile:
            shape = list(tile.shape)
            shape[1] = self.pad_tile - bounds[-1]
            parts.append(jnp.zeros(shape, tile.dtype))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)


def _pin(*vals):
    """Pin ``vals`` on opposite sides of the scheduler (identity on values)."""
    if not hasattr(jax.lax, "optimization_barrier"):
        return vals
    try:
        return jax.lax.optimization_barrier(vals)
    except NotImplementedError:
        # vmap-emulated rings have no batching rule for the barrier; program
        # order alone still issues the hop before the GEMM consuming it.
        return vals


def _resolve_allgather(schedule: Optional[RingSchedule], *, d: int,
                       s_loc: int) -> RingSchedule:
    if schedule is None:
        # default: dense even split over the axis, one local tile per device
        return RingSchedule.dense(d, s_loc)
    if schedule.num_devices != d:
        raise ValueError(
            f"schedule covers {schedule.num_devices} devices "
            f"but the ring has {d}"
        )
    if schedule.pad_tile != s_loc:
        raise ValueError(
            f"local sequence tile is {s_loc} rows but the schedule's "
            f"pad_tile={schedule.pad_tile}; the ring AllGather moves "
            "whole local tiles"
        )
    return schedule


def _resolve_scatter(schedule: Optional[RingSchedule], *, d: int,
                     s: int) -> RingSchedule:
    if schedule is None:
        if s % d:
            raise ValueError(
                f"sequence {s} does not divide over a ring of {d} devices; "
                "pass a schedule, or run a ragged layout "
                "(ExecPlan.ring_schedule / RingSchedule.ragged)"
            )
        return RingSchedule.dense(d, s // d)
    if schedule.num_devices != d:
        raise ValueError(
            f"schedule covers {schedule.num_devices} devices "
            f"but the ring has {d}"
        )
    if d * schedule.pad_tile != s:
        raise ValueError(
            f"tile_size={schedule.pad_tile} x {d} devices != sequence "
            f"{s}; the ring ReduceScatter consumes exactly one tile per "
            "device per step"
        )
    return schedule


def ring_allgather_matmul(x_local, w_local, axis_name: str,
                          *, schedule: Optional[RingSchedule] = None):
    """Overlapped computation of ``all_gather(x, seq) @ w_local``.

    x_local: (B, S_loc, d)   — this device's sequence tile (paper's H_i)
    w_local: (d, F_loc)      — this device's column shard (paper's W_i^D)
    schedule: the ring program (``RingSchedule``); defaults to a dense
              even-split schedule over the axis.  ``pad_tile`` must equal
              ``S_loc`` (every device contributes one tile per ring step).
    returns: (B, D*pad_tile, F_loc) — full-sequence activation (padded
             layout when ragged), local columns.

    Step r computes the GEMM for the tile received r hops ago while the next
    tile is in flight; the final step does no communication (paper §III-D-1).
    """
    d = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    b, s_loc, _ = x_local.shape
    sched = _resolve_allgather(schedule, d=d, s_loc=s_loc)
    vs = jnp.asarray(sched.valid_sizes) if sched.is_masked else None
    gemm_fn = sched.gemm
    ts = sched.pad_tile
    f_loc = w_local.shape[1]

    out = jnp.zeros((b, d * ts, f_loc), x_local.dtype)
    tile = x_local
    for r in range(d):
        src = sched.source(idx, r)  # owner of the tile we hold at step r
        nxt = None
        if sched.double_buffer and r != d - 1:
            # issue hop r before the GEMM that frees its buffer and pin the
            # two on opposite sides of the scheduler: the next tile is in
            # flight while this tile computes
            nxt = sched.ship(tile, axis_name, r)
            nxt, tile = _pin(nxt, tile)
        if gemm_fn is not None:
            # valid-length kernel: masks pad rows itself and skips pad blocks
            vrows = None if vs is None else vs[src]
            part = gemm_fn(tile, w_local, vrows)
        else:
            if vs is not None:
                row_ok = jnp.arange(ts) < vs[src]
                gemm_in = jnp.where(row_ok[None, :, None], tile, 0)
            else:
                gemm_in = tile
            part = jnp.einsum("bsd,df->bsf", gemm_in, w_local)
        out = jax.lax.dynamic_update_slice(out, part, (0, src * ts, 0))
        if r != d - 1:
            # send current tile forward; receive the next from the ring
            tile = nxt if nxt is not None else sched.ship(tile, axis_name, r)
    return out


def matmul_ring_reducescatter(h_local, w_local, axis_name: str,
                              *, schedule: Optional[RingSchedule] = None):
    """Overlapped computation of ``psum_scatter(h_local @ w_local, seq)``.

    h_local: (B, S, F_loc)   — full sequence, this device's column shard (E_i)
    w_local: (F_loc, d)      — row shard of the second GEMM (W_i^E)
    schedule: the ring program; defaults to a dense even-split schedule.
              ``D * pad_tile`` must equal ``S`` (the ring consumes exactly
              one tile per device per step).
    returns: (B, pad_tile, d) — this device's sequence tile of the summed
             output.

    Schedule (paper §III-D-2): at step r device i GEMMs its tile
    (i - r + D - 1) mod D and adds the partial sum arriving from its
    predecessor, which processed the same tile one step earlier.  After D
    steps device i owns the fully-reduced tile i.
    """
    d = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    b, s, _ = h_local.shape
    sched = _resolve_scatter(schedule, d=d, s=s)
    vs = jnp.asarray(sched.valid_sizes) if sched.is_masked else None
    gemm_fn = sched.gemm
    ts = sched.pad_tile

    acc = None
    for r in range(d):
        t = jnp.mod(idx - r + d - 1, d)  # tile index to process this step
        tile = jax.lax.dynamic_slice(
            h_local, (0, t * ts, 0), (b, ts, h_local.shape[2])
        )
        inc = None
        if acc is not None and sched.double_buffer:
            # the partial accumulator hop (it carries tile t's partial sums
            # from the predecessor) is issued before this step's GEMM
            inc = sched.ship(acc, axis_name, r)
            inc, tile = _pin(inc, tile)
        if gemm_fn is not None:
            part = gemm_fn(tile, w_local, None if vs is None else vs[t])
        else:
            if vs is not None:
                row_ok = jnp.arange(ts) < vs[t]
                tile = jnp.where(row_ok[None, :, None], tile, 0)
            part = jnp.einsum("bsf,fd->bsd", tile, w_local)
        if acc is None:
            acc = part
        else:
            acc = part + (inc if inc is not None
                          else sched.ship(acc, axis_name, r))
    return acc


# --- unoverlapped references (the paper's "sync" baseline schedule) -----------

def _global_valid_mask(vs: np.ndarray, tile_size: int) -> np.ndarray:
    """(D*tile_size,) bool: valid rows of the concatenated padded layout."""
    return np.concatenate([np.arange(tile_size) < v for v in vs])


def sync_allgather_matmul(x_local, w_local, axis_name: str,
                          *, schedule: Optional[RingSchedule] = None):
    """Unoverlapped oracle for ``ring_allgather_matmul`` (same schedule arg).

    Transport mode and double buffering are ring-only concerns and are
    ignored here; only the schedule's valid row counts and gemm hook apply.
    """
    d = _axis_size(axis_name)
    sched = _resolve_allgather(schedule, d=d, s_loc=x_local.shape[1])
    vs = sched.valid_sizes if sched.is_masked else None
    xg = jax.lax.all_gather(x_local, axis_name, axis=1, tiled=True)
    if vs is not None:
        # the gathered sequence mixes per-tile valid counts, which the
        # prefix-valid kernel cannot express: mask rows here either way
        # (a shedding gemm still skips pad column/contraction blocks)
        mask = _global_valid_mask(vs, sched.pad_tile)
        xg = jnp.where(jnp.asarray(mask)[None, :, None], xg, 0)
    if sched.gemm is not None:
        return sched.gemm(xg, w_local, None)
    return jnp.einsum("bsd,df->bsf", xg, w_local)


def sync_matmul_reducescatter(h_local, w_local, axis_name: str,
                              *, schedule: Optional[RingSchedule] = None):
    """Unoverlapped oracle for ``matmul_ring_reducescatter``."""
    d = _axis_size(axis_name)
    sched = _resolve_scatter(schedule, d=d, s=h_local.shape[1])
    vs = sched.valid_sizes if sched.is_masked else None
    if vs is not None:
        mask = _global_valid_mask(vs, sched.pad_tile)
        h_local = jnp.where(jnp.asarray(mask)[None, :, None], h_local, 0)
    if sched.gemm is not None:
        out = sched.gemm(h_local, w_local, None)
    else:
        out = jnp.einsum("bsf,fd->bsd", h_local, w_local)
    return jax.lax.psum_scatter(out, axis_name, scatter_dimension=1, tiled=True)
