"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory with exponential gating and a true sequential recurrence).

TP mapping (DESIGN.md §4): xlstm-350m has 4 heads — fewer than the 16-way
model axis — so TP shards the *inner feature* dims.  mLSTM: the state's
value dim is model-sharded (the k·q contraction side stays replicated), so
the recurrence is comm-free.  sLSTM: the per-step recurrence mixes the whole
per-head state, so the recurrent core is replicated and TP re-enters at the
row-parallel down projection (ReduceScatter exit) — an inherent limit of
sequential recurrences, noted in DESIGN.md.

States are fp32 with max-stabilizer log-space gating (xLSTM eq. 15/24).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import connective_norm, connective_residual
from repro.models.sharding import constrain


def _dims(cfg: ModelConfig):
    di = int(cfg.d_model * cfg.proj_factor)
    nh = cfg.num_heads
    dh = di // nh
    return di, nh, dh


def _mh_rmsnorm(h, scale):
    """Per-head RMS norm: h (..., nh, dh); scale (nh*dh,)."""
    dt = h.dtype
    hf = h.astype(jnp.float32)
    var = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    out = hf * jax.lax.rsqrt(var + 1e-6)
    s = (1.0 + scale.astype(jnp.float32)).reshape(h.shape[-2], h.shape[-1])
    return (out * s).astype(dt)


# --- mLSTM -------------------------------------------------------------------

def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Dict:
    _, nh, dh = _dims(cfg)
    return {
        "c": jnp.zeros((batch, nh, dh, dh), jnp.float32),  # (v-dim, k-dim)
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def mlstm_cache_struct(cfg: ModelConfig, batch: int):
    _, nh, dh = _dims(cfg)
    return {
        "c": jax.ShapeDtypeStruct((batch, nh, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, nh, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, nh), jnp.float32),
    }


MLSTM_CACHE_AXES = {
    "c": ("batch", None, "inner", None),
    "n": ("batch", None, None),
    "m": ("batch", None),
}


def _mlstm_step(state, inp):
    """One recurrent step. state: (c (B,nh,dv,dk), n (B,nh,dk), m (B,nh)).
    inp: q,k,v (B,nh,d*), i_raw,f_raw (B,nh)."""
    c, n, m = state
    q, k, v, i_raw, f_raw = inp
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m, i_raw)
    i = jnp.exp(i_raw - m_new)
    f = jnp.exp(logf + m - m_new)
    c = f[..., None, None] * c + i[..., None, None] * (v[..., :, None] * k[..., None, :])
    n = f[..., None] * n + i[..., None] * k
    num = jnp.einsum("bnvk,bnk->bnv", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bnk,bnk->bn", n, q)), jnp.exp(-m_new))
    h = num / den[..., None]
    return (c, n, m_new), h


def mlstm_scan(q, k, v, i_raw, f_raw, state):
    """Recurrent scan over time (reference/oracle; O(S) carries make it
    training-infeasible — use mlstm_chunked).  q,k,v: (B,S,nh,d*) fp32;
    gates (B,S,nh).  Returns h (B,S,nh,dv) and final state."""

    def step(carry, xs):
        return _mlstm_step(carry, xs)

    xs = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        i_raw.transpose(1, 0, 2),
        f_raw.transpose(1, 0, 2),
    )
    state, h = jax.lax.scan(step, state, xs)
    return h.transpose(1, 0, 2, 3), state


def mlstm_chunked(q, k, v, i_raw, f_raw, state, chunk: int):
    """Chunkwise-parallel mLSTM (exact, same stabilizer semantics as the
    recurrent step): intra-chunk attention-like weights in log space +
    inter-chunk recurrence over chunk boundaries only.  Memory: O(S/chunk)
    carried states instead of O(S)."""
    b, s, nh, dk = k.shape
    dv = v.shape[-1]
    nc = s // chunk
    assert s % chunk == 0

    def to_chunks(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).transpose(
            1, 0, *range(2, x.ndim + 1)
        )

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    ic, fc = to_chunks(i_raw), to_chunks(f_raw)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(carry, xs):
        c_prev, n_prev, m_prev = carry          # (B,nh,dv,dk), (B,nh,dk), (B,nh)
        qt, kt, vt, it, ft = xs                 # (B,L,nh,*)
        logf = jax.nn.log_sigmoid(ft)           # (B,L,nh)
        bcum = jnp.cumsum(logf, axis=1)         # inclusive decay sums
        # stabilizer: m_t = max(m_prev + b_t, max_{tau<=t}(b_t - b_tau + i_tau))
        gi = jax.lax.cummax(it - bcum, axis=1)
        m_intra = bcum + gi
        m_t = jnp.maximum(m_prev[:, None] + bcum, m_intra)  # (B,L,nh)
        # intra-chunk weights w[t,tau] = exp(b_t - b_tau + i_tau - m_t)
        logw = (
            bcum[:, :, None, :] - bcum[:, None, :, :] + it[:, None, :, :]
            - m_t[:, :, None, :]
        )  # (B, t, tau, nh)
        w = jnp.where(tri[None, :, :, None], jnp.exp(logw), 0.0)
        # attention-like intra term
        qk = jnp.einsum("blnk,btnk->bltn", qt, kt)     # (B, t, tau, nh)
        intra = jnp.einsum("bltn,bltn,btnv->blnv", w, qk, vt)
        n_intra = jnp.einsum("bltn,btnk->blnk", w, kt)
        # inter-chunk (state) term
        decay = jnp.exp(m_prev[:, None] + bcum - m_t)   # (B,L,nh)
        inter = jnp.einsum("blnk,bnvk->blnv", qt, c_prev) * decay[..., None]
        n_inter = n_prev[:, None] * decay[..., None]
        num = intra + inter
        n_t = n_intra + n_inter
        den = jnp.maximum(
            jnp.abs(jnp.einsum("blnk,blnk->bln", n_t, qt)), jnp.exp(-m_t)
        )
        h = num / den[..., None]
        # carry update to the chunk end (position L-1)
        b_l = bcum[:, -1]                                # (B,nh)
        m_new = m_t[:, -1]
        c_decay = jnp.exp(m_prev + b_l - m_new)
        wl = jnp.exp(bcum[:, -1:, :] - bcum + it - m_new[:, None])  # (B,L,nh)
        c_new = c_decay[..., None, None] * c_prev + jnp.einsum(
            "btn,btnv,btnk->bnvk", wl, vt, kt
        )
        n_new = c_decay[..., None] * n_prev + jnp.einsum("btn,btnk->bnk", wl, kt)
        return (c_new, n_new, m_new), h

    carry, h = jax.lax.scan(chunk_step, state, (qc, kc, vc, ic, fc))
    h = h.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, dv)
    return h, carry


def mlstm_block(
    p: Dict,
    x,
    cfg: ModelConfig,
    *,
    mode: str,
    cache: Optional[Dict],
    rng,
    deterministic: bool,
) -> Tuple[jax.Array, Optional[Dict]]:
    di, nh, dh = _dims(cfg)
    xn = connective_norm(x, p["ln"], cfg.norm)
    xg = constrain(xn, ("batch", None, "embed"))  # AllGather: enter TP block
    b, s, _ = xg.shape

    up = jnp.einsum("bsd,de->bse", xg, p["w_up"])
    up = constrain(up, ("batch", None, "inner"))
    xi, og = up[..., :di], up[..., di:]
    xi_h = xi.reshape(b, s, nh, dh)

    # q/k on the contracted (replicated) side; v on the sharded value side
    q = jnp.einsum("bsnd,nde->bsne", xi_h, p["wq"]).astype(jnp.float32)
    k = jnp.einsum("bsnd,nde->bsne", xi_h, p["wk"]).astype(jnp.float32) / jnp.sqrt(dh)
    v = jnp.einsum("bsnd,nde->bsne", xi_h, p["wv"]).astype(jnp.float32)
    q = constrain(q, ("batch", None, None, None))
    k = constrain(k, ("batch", None, None, None))
    v = constrain(v, ("batch", None, None, "inner"))
    gates = jnp.einsum("bsnd,ndg->bsng", xi_h, p["w_if"]).astype(jnp.float32) + p[
        "b_if"
    ].astype(jnp.float32)
    i_raw, f_raw = gates[..., 0], gates[..., 1]

    state = cache
    if state is None:
        state = init_mlstm_cache(cfg, b)
    if mode == "decode":
        (c, n, m), h = _mlstm_step(
            (state["c"], state["n"], state["m"]),
            (q[:, 0], k[:, 0], v[:, 0], i_raw[:, 0], f_raw[:, 0]),
        )
        h = h[:, None]
        new_cache = {"c": c, "n": n, "m": m}
    else:
        st = (state["c"], state["n"], state["m"])
        if s % cfg.mlstm_chunk == 0 and s > cfg.mlstm_chunk:
            h, (c, n, m) = mlstm_chunked(q, k, v, i_raw, f_raw, st, cfg.mlstm_chunk)
        else:
            h, (c, n, m) = mlstm_scan(q, k, v, i_raw, f_raw, st)
        new_cache = {"c": c, "n": n, "m": m} if mode == "prefill" else None

    h = _mh_rmsnorm(h.astype(x.dtype), p["mh_norm"]["scale"])
    h = constrain(h, ("batch", None, None, "inner"))
    merged = (h.reshape(b, -1, di)) * jax.nn.silu(og)
    out = jnp.einsum("bse,ed->bsd", merged, p["w_down"])  # row-parallel partials
    x = connective_residual(x, out, cfg.dropout_rate, rng, deterministic)
    return x, new_cache


# --- sLSTM -------------------------------------------------------------------

def init_slstm_cache(cfg: ModelConfig, batch: int) -> Dict:
    _, nh, dh = _dims(cfg)
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"h": z, "c": z, "n": z + 1e-6, "m": jnp.full((batch, nh, dh), -1e30, jnp.float32)}


def slstm_cache_struct(cfg: ModelConfig, batch: int):
    _, nh, dh = _dims(cfg)
    sd = jax.ShapeDtypeStruct((batch, nh, dh), jnp.float32)
    return {"h": sd, "c": sd, "n": sd, "m": sd}


SLSTM_CACHE_AXES = {k: ("batch", None, None) for k in ("h", "c", "n", "m")}


def _slstm_step(state, x_part, w_rec):
    """x_part: (B,4,nh,dh) fp32 pre-activations from the input projection."""
    h, c, n, m = state
    rec = jnp.einsum("bnd,ndge->bgne", h, w_rec.astype(jnp.float32))
    raw = x_part + rec
    i_raw, f_raw, z_raw, o_raw = raw[:, 0], raw[:, 1], raw[:, 2], raw[:, 3]
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m, i_raw)
    i = jnp.exp(i_raw - m_new)
    f = jnp.exp(logf + m - m_new)
    c_new = f * c + i * jnp.tanh(z_raw)
    n_new = f * n + i
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new), h_new


def slstm_block(
    p: Dict,
    x,
    cfg: ModelConfig,
    *,
    mode: str,
    cache: Optional[Dict],
    rng,
    deterministic: bool,
) -> Tuple[jax.Array, Optional[Dict]]:
    di, nh, dh = _dims(cfg)
    xn = connective_norm(x, p["ln"], cfg.norm)
    xg = constrain(xn, ("batch", None, "embed"))
    b, s, _ = xg.shape

    x_part = (
        jnp.einsum("bsd,dgne->bsgne", xg, p["w_in"]) + p["b_in"]
    ).astype(jnp.float32)

    state = cache
    if state is None:
        state = init_slstm_cache(cfg, b)
    st = (state["h"], state["c"], state["n"], state["m"])

    if mode == "decode":
        st, h = _slstm_step(st, x_part[:, 0], p["w_rec"])
        h_seq = h[:, None]
    else:
        def step(carry, xp):
            return _slstm_step(carry, xp, p["w_rec"])

        st, h_seq = jax.lax.scan(step, st, x_part.transpose(1, 0, 2, 3, 4))
        h_seq = h_seq.transpose(1, 0, 2, 3)

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"h": st[0], "c": st[1], "n": st[2], "m": st[3]}

    h_seq = _mh_rmsnorm(h_seq.astype(x.dtype), p["mh_norm"]["scale"])
    merged = constrain(h_seq.reshape(b, s, di), ("batch", None, "inner"))
    out = jnp.einsum("bse,ed->bsd", merged, p["w_down"])  # row-parallel partials
    x = connective_residual(x, out, cfg.dropout_rate, rng, deterministic)
    return x, new_cache
