from repro.models.params import abstract_params, init_params, model_spec, partition_specs
from repro.models.transformer import apply_model

__all__ = ["abstract_params", "init_params", "model_spec", "partition_specs", "apply_model"]
