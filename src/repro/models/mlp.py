"""MLP blocks (the paper's second TP target): first GEMM column-split along
``ffn``, second GEMM row-split to match — no sync inside the block
(§III-B-2); entry/exit collectives come from the connective constraints."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import connective_norm, connective_residual
from repro.models.sharding import constrain


def mlp_apply(p: Dict, x, cfg: ModelConfig):
    """x: (B, S, d) full-seq (TP region). Returns partial-sum (B, S, d)."""
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * jnp.einsum(
            "bsd,df->bsf", x, p["w_up"]
        )
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * jnp.einsum(
            "bsd,df->bsf", x, p["w_up"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
    h = constrain(h, ("batch", None, "ffn"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


def mlp_block(p: Dict, x, cfg: ModelConfig, *, rng, deterministic: bool):
    xn = connective_norm(x, p["ln2"], cfg.norm)
    xg = constrain(xn, ("batch", None, "embed"))  # AllGather
    out = mlp_apply(p["mlp"], xg, cfg)
    return connective_residual(x, out, cfg.dropout_rate, rng, deterministic)  # ReduceScatter
