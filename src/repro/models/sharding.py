"""Logical-axis sharding rules (MaxText-style) — the bridge between model
code and the Galaxy HMP layout.

Model code annotates activations with *logical* axis names via
``constrain(x, ("batch", "seq", "embed"))``.  A ``Rules`` table maps logical
names to mesh axes; the HMP layout is expressed entirely through this table:

* ``heads`` / ``ffn`` / ``experts``  -> "model"   (TP blocks: MHA + MLP/MoE)
* ``seq``                            -> "model"   (SP connective blocks)
* ``batch``                          -> ("pod", "data")

GSPMD then materializes exactly the paper's synchronization points: the
transition from a seq-sharded connective block into a head-sharded TP block
is an AllGather; the partial-sum exit of a row-parallel GEMM constrained
back to seq-sharded is a ReduceScatter (§III-B-4 of the paper).

Outside a mesh context the constraints are no-ops, so the same model code
runs single-device (tests) and multi-pod (dry-run).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass
class Rules:
    """Mapping from logical axis names to mesh axes (or None=replicated)."""

    mapping: Dict[str, MeshAxes] = field(default_factory=dict)
    mesh: Optional[Mesh] = None

    def axis_size(self, name: str) -> int:
        """Product of mesh-axis sizes a logical axis maps to (1 if unmapped)."""
        ax = self.mapping.get(name)
        if ax is None or self.mesh is None:
            return 1
        if isinstance(ax, str):
            ax = (ax,)
        size = 1
        for a in ax:
            size *= self.mesh.shape[a]
        return size

    def spec(self, names: Sequence[Optional[str]], shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for logical axis names.  If ``shape`` is given,
        mesh axes that do not evenly divide a dimension are dropped (e.g.
        8 KV heads on a 16-way model axis -> replicated); for tuple
        mappings the prefix that still divides is kept."""
        axes = []
        used: set = set()

        def resolve(name, dim):
            if name is None:
                return None
            ax = self.mapping.get(name)
            if ax is None:
                return None
            if isinstance(ax, str):
                ax = (ax,)
            ax = tuple(a for a in ax if a not in used)
            if not ax:
                return None
            if dim is not None and self.mesh is not None:
                kept = []
                prod = 1
                for a in ax:
                    if dim % (prod * self.mesh.shape[a]) == 0:
                        kept.append(a)
                        prod *= self.mesh.shape[a]
                    else:
                        break
                ax = tuple(kept)
                if not ax:
                    return None
            used.update(ax)
            return ax if len(ax) > 1 else ax[0]

        dims = list(shape) if shape is not None else [None] * len(names)
        for n, d in zip(names, dims):
            axes.append(resolve(n, d))
        return P(*axes)


_STATE = threading.local()


def current_rules() -> Optional[Rules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[Rules]):
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def constrain(x, names: Sequence[Optional[str]]):
    """Apply a (shape-aware) sharding constraint if rules are active."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = rules.spec(names, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def logical_axis_size(name: str) -> int:
    """Mesh extent a logical axis would shard over under the active rules."""
    rules = current_rules()
    if rules is None:
        return 1
    return rules.axis_size(name)


def logical_to_spec(names: Sequence[Optional[str]], rules: Rules) -> P:
    return rules.spec(names)


# ---------------------------------------------------------------------------
# Rule tables for the production shapes (see DESIGN.md §5).
# ---------------------------------------------------------------------------

def make_rules(
    mesh: Optional[Mesh],
    mode: str,
    *,
    multi_pod: bool = False,
    batch_size: int = 0,
    hmp_sequence_parallel: bool = True,
    serve_weights_model_only: bool = False,
) -> Rules:
    """Build the logical->mesh table for a given execution mode.

    mode: "train" | "prefill" | "decode" | "decode_long"
    ``hmp_sequence_parallel=False`` gives the Megatron-TP baseline layout
    (connective blocks replicated — the redundant-compute baseline the
    paper compares against).
    ``serve_weights_model_only=True`` drops the FSDP (data-axis) shard of
    the weights for decode modes: weights live model-sharded only, removing
    the per-step weight AllGather at the cost of num_data_shards x weight
    memory (see EXPERIMENTS.md §Perf, qwen1.5-110b decode hillclimb).
    """
    dp: MeshAxes = ("pod", "data") if multi_pod else ("data",)
    m = "model"

    mapping: Dict[str, MeshAxes] = {
        # weights
        "embed_w": "data",        # FSDP shard of the embedding/contraction dim
        "heads_w": m,
        "kv_heads_w": m,
        "ffn_w": m,
        "experts_w": m,
        "vocab_w": m,
        "lru_w": m,
        "inner_w": m,
        # activations
        "batch": dp,
        "embed": None,
        "heads": m,
        "kv_heads": m,
        "ffn": m,
        "experts": m,
        "vocab": m,
        "lru": m,
        "inner": m,
        "img_seq": None,
        "expert_group": dp,
    }

    if mode == "train" or mode == "prefill":
        mapping["seq"] = m if hmp_sequence_parallel else None
        mapping["kv_seq"] = None
    elif mode == "decode":
        # one-token step: SP is vacuous; shard the KV cache along sequence.
        # Attention runs flash-decoding style: q/scores replicated over the
        # model axis, cache seq-sharded, softmax reductions psum'd — so
        # activation `heads` must NOT claim the model axis (a heads-sharded
        # q would force a full cache reshard every layer).
        mapping["seq"] = None
        mapping["kv_seq"] = m
        mapping["heads"] = None
        mapping["kv_heads"] = None
    elif mode == "decode_long":
        # batch=1: batch axes are vacuous; context-parallel cache over the
        # data axis as well as model
        mapping["batch"] = None
        mapping["seq"] = None
        mapping["kv_seq"] = (("pod", "data", m) if multi_pod else ("data", m))
        mapping["heads"] = None
        mapping["kv_heads"] = None
        mapping["expert_group"] = None
    else:
        raise ValueError(f"unknown mode {mode}")

    # batch=1 shapes cannot shard batch
    if batch_size == 1:
        mapping["batch"] = None

    if serve_weights_model_only and mode in ("prefill", "decode", "decode_long"):
        mapping["embed_w"] = None

    return Rules(mapping=mapping, mesh=mesh)
