"""Structural parameter descriptions.

Every architecture's parameter tree is described once as a pytree of
``PSpec`` (shape + logical axes + initializer).  From that single
description we derive:

* ``init_params``      — materialized arrays (tests, examples, training)
* ``abstract_params``  — ShapeDtypeStructs (multi-pod dry-run: no allocation)
* ``partition_specs``  — PartitionSpec tree for pjit in_shardings

Layers that repeat are *stacked* along a leading group dimension and
executed with ``jax.lax.scan`` so the lowered HLO stays small even for
100-layer models (critical: dry-run compiles 512-way SPMD on one CPU core).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.sharding import Rules

EXPERT_PAD = 16   # expert-parallel degree the expert dim must divide by
VOCAB_PAD = 256


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | lru_a
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def padded_vocab(cfg: ModelConfig) -> int:
    return cfg.padded_vocab(VOCAB_PAD) if cfg.vocab_size >= VOCAB_PAD else cfg.vocab_size


def padded_experts(cfg: ModelConfig) -> int:
    if not cfg.is_moe:
        return 0
    if cfg.num_experts >= EXPERT_PAD:
        return cfg.padded_experts(EXPERT_PAD)
    return cfg.num_experts


# --- per-block specs ----------------------------------------------------------

def _norm_spec(cfg: ModelConfig) -> Dict[str, PSpec]:
    d = cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": PSpec((d,), (None,), "zeros")}
    return {"scale": PSpec((d,), (None,), "ones"), "bias": PSpec((d,), (None,), "zeros")}


def _inner_norm_spec(width: int) -> Dict[str, PSpec]:
    return {"scale": PSpec((width,), (None,), "zeros")}


def _mlp_spec(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, ff = cfg.d_model, cfg.d_ff
    out = {
        "w_up": PSpec((d, ff), ("embed_w", "ffn_w")),
        "w_down": PSpec((ff, d), ("ffn_w", "embed_w")),
    }
    if cfg.activation in ("swiglu", "geglu"):
        out["w_gate"] = PSpec((d, ff), ("embed_w", "ffn_w"))
    return out


def _moe_spec(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, ff = cfg.d_model, cfg.d_ff
    e = padded_experts(cfg)
    out = {
        "router": PSpec((d, e), ("embed_w", None)),
        "we_up": PSpec((e, d, ff), ("experts_w", "embed_w", None)),
        "we_down": PSpec((e, ff, d), ("experts_w", None, "embed_w")),
    }
    if cfg.activation in ("swiglu", "geglu"):
        out["we_gate"] = PSpec((e, d, ff), ("experts_w", "embed_w", None))
    return out


def _attn_spec(cfg: ModelConfig, cross: bool) -> Dict[str, PSpec]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p: Dict[str, PSpec] = {
        "ln1": _norm_spec(cfg),
        "wq": PSpec((d, h, hd), ("embed_w", "heads_w", None)),
        "wk": PSpec((d, kv, hd), ("embed_w", "kv_heads_w", None)),
        "wv": PSpec((d, kv, hd), ("embed_w", "kv_heads_w", None)),
        "wo": PSpec((h, hd, d), ("heads_w", None, "embed_w")),
        "ln2": _norm_spec(cfg),
    }
    if cfg.qkv_bias:
        p["bq"] = PSpec((h, hd), ("heads_w", None), "zeros")
        p["bk"] = PSpec((kv, hd), ("kv_heads_w", None), "zeros")
        p["bv"] = PSpec((kv, hd), ("kv_heads_w", None), "zeros")
    if cross:
        p["xgate"] = PSpec((1,), (None,), "zeros")
        p["kv_norm"] = _norm_spec(cfg)
    if cfg.is_moe:
        p["moe"] = _moe_spec(cfg)
    elif cfg.d_ff > 0:
        p["mlp"] = _mlp_spec(cfg)
    return p


def _rec_spec(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, w, cw = cfg.d_model, cfg.lru_width, cfg.conv_width
    p: Dict[str, PSpec] = {
        "ln1": _norm_spec(cfg),
        "w_in": PSpec((d, w), ("embed_w", "lru_w")),
        "w_gate_in": PSpec((d, w), ("embed_w", "lru_w")),
        "conv_w": PSpec((cw, w), (None, "lru_w")),
        "conv_b": PSpec((w,), ("lru_w",), "zeros"),
        # diagonal RG-LRU gates (block-diagonal in Griffin; see DESIGN.md §2)
        "a_param": PSpec((w,), ("lru_w",), "lru_a"),
        "gate_a_w": PSpec((w,), ("lru_w",), "zeros"),
        "gate_a_b": PSpec((w,), ("lru_w",), "zeros"),
        "gate_x_w": PSpec((w,), ("lru_w",), "zeros"),
        "gate_x_b": PSpec((w,), ("lru_w",), "zeros"),
        "w_out": PSpec((w, d), ("lru_w", "embed_w")),
        "ln2": _norm_spec(cfg),
    }
    if cfg.d_ff > 0:
        p["mlp"] = _mlp_spec(cfg)
    return p


def _mlstm_spec(cfg: ModelConfig) -> Dict[str, PSpec]:
    d = cfg.d_model
    di = int(d * cfg.proj_factor)
    nh = cfg.num_heads
    dh = di // nh
    # xlstm-350m has only 4 heads — far fewer than the 16-way model axis —
    # so TP shards the per-head feature dims (dh / inner), not the heads.
    # q/k live on the contracted side of the recurrence and stay replicated;
    # v and the state's value dim are model-sharded (see models/xlstm.py).
    return {
        "ln": _norm_spec(cfg),
        "w_up": PSpec((d, 2 * di), ("embed_w", "inner_w")),
        "wq": PSpec((nh, dh, dh), (None, None, None)),
        "wk": PSpec((nh, dh, dh), (None, None, None)),
        "wv": PSpec((nh, dh, dh), (None, None, "inner_w")),
        "w_if": PSpec((nh, dh, 2), (None, None, None), "zeros"),
        "b_if": PSpec((nh, 2), (None, None), "zeros"),
        "mh_norm": _inner_norm_spec(di),
        "w_down": PSpec((di, d), ("inner_w", "embed_w")),
    }


def _slstm_spec(cfg: ModelConfig) -> Dict[str, PSpec]:
    d = cfg.d_model
    di = int(d * cfg.proj_factor)
    nh = cfg.num_heads
    dh = di // nh
    # sLSTM's recurrence mixes the full per-head state every step, so the
    # recurrent internals stay replicated over the model axis; TP re-enters
    # at the down projection (row-parallel -> ReduceScatter exit).
    return {
        "ln": _norm_spec(cfg),
        "w_in": PSpec((d, 4, nh, dh), ("embed_w", None, None, None)),
        "b_in": PSpec((4, nh, dh), (None, None, None), "zeros"),
        # block-diagonal (per-head) recurrent matrix R: raw_t += h_{t-1} R
        "w_rec": PSpec((nh, dh, 4, dh), (None, None, None, None), "normal", 0.01),
        "mh_norm": _inner_norm_spec(di),
        "w_down": PSpec((di, d), ("inner_w", "embed_w")),
    }


_BLOCK_SPECS = {
    "attn": lambda cfg: _attn_spec(cfg, cross=False),
    "xattn": lambda cfg: _attn_spec(cfg, cross=True),
    "rec": _rec_spec,
    "mlstm": _mlstm_spec,
    "slstm": _slstm_spec,
}


# --- whole model ------------------------------------------------------------

def model_spec(cfg: ModelConfig) -> Dict:
    """PSpec pytree.  'groups' subtrees are stacked with leading dim
    cfg.num_groups (handled by the consumers below); 'tail' subtrees are
    per-layer."""
    d = cfg.d_model
    v = padded_vocab(cfg)
    spec: Dict = {"embed": {}, "groups": {}, "tail": {}, "final_norm": _norm_spec(cfg)}
    if cfg.input_mode == "token":
        spec["embed"]["tok"] = PSpec((v, d), ("vocab_w", "embed_w"), "normal", 0.02)
    if not cfg.tie_embeddings:
        cb = max(1, cfg.num_codebooks)
        spec["head"] = {"w": PSpec((cb, d, v), (None, "embed_w", "vocab_w"))}
    for i, kind in enumerate(cfg.block_pattern):
        spec["groups"][f"b{i}_{kind}"] = _BLOCK_SPECS[kind](cfg)
    for i, kind in enumerate(cfg.tail_pattern):
        spec["tail"][f"t{i}_{kind}"] = _BLOCK_SPECS[kind](cfg)
    return spec


def _is_grouped(path: Tuple) -> bool:
    return len(path) > 0 and getattr(path[0], "key", None) == "groups"


def _leaf_shape(cfg: ModelConfig, path, ps: PSpec) -> Tuple[int, ...]:
    if _is_grouped(path):
        return (cfg.num_groups,) + ps.shape
    return ps.shape


def _leaf_axes(path, ps: PSpec) -> Tuple[Optional[str], ...]:
    if _is_grouped(path):
        return (None,) + ps.axes
    return ps.axes


def abstract_params(cfg: ModelConfig, rules: Optional[Rules] = None):
    """ShapeDtypeStruct tree (optionally with shardings attached)."""
    dtype = jnp.dtype(cfg.param_dtype)
    spec = model_spec(cfg)

    def make(path, ps: PSpec):
        shape = _leaf_shape(cfg, path, ps)
        sharding = None
        if rules is not None and rules.mesh is not None:
            sharding = jax.sharding.NamedSharding(
                rules.mesh, rules.spec(_leaf_axes(path, ps), shape=shape)
            )
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)

    return jax.tree_util.tree_map_with_path(make, spec, is_leaf=lambda x: isinstance(x, PSpec))


def partition_specs(cfg: ModelConfig, rules: Rules):
    spec = model_spec(cfg)

    def make(path, ps: PSpec):
        return rules.spec(_leaf_axes(path, ps), shape=_leaf_shape(cfg, path, ps))

    return jax.tree_util.tree_map_with_path(make, spec, is_leaf=lambda x: isinstance(x, PSpec))


def init_params(cfg: ModelConfig, key: jax.Array):
    dtype = jnp.dtype(cfg.param_dtype)
    spec = model_spec(cfg)
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(
        spec, is_leaf=lambda x: isinstance(x, PSpec)
    )
    keys = jax.random.split(key, len(leaves_with_paths))

    def init_one(k, path, ps: PSpec):
        shape = _leaf_shape(cfg, path, ps)
        if ps.init == "zeros":
            return jnp.zeros(shape, dtype)
        if ps.init == "ones":
            return jnp.ones(shape, dtype)
        if ps.init == "lru_a":
            # Griffin init: decay a in [0.9, 0.999]; a_param = softplus^-1(c^-1 * -log a)
            u = jax.random.uniform(k, shape, jnp.float32, 0.9, 0.999)
            inner = -jnp.log(u) / 8.0
            ap = jnp.log(jnp.expm1(jnp.clip(inner, 1e-8, None)))
            return ap.astype(dtype)
        # fan-in scaled normal
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = min(ps.scale, 1.0 / np.sqrt(max(fan_in, 1)))
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    leaves = [init_one(k, p, ps) for k, (p, ps) in zip(keys, leaves_with_paths)]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def param_bytes(cfg: ModelConfig) -> int:
    spec = model_spec(cfg)
    total = 0
    for path, ps in jax.tree_util.tree_flatten_with_path(
        spec, is_leaf=lambda x: isinstance(x, PSpec)
    )[0]:
        total += int(np.prod(_leaf_shape(cfg, path, ps)))
    return total * jnp.dtype(cfg.param_dtype).itemsize
