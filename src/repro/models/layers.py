"""Shared layer primitives: norms, positions, dropout, the Galaxy
"connective block" (dropout + residual add + norm — the SP region)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.sharding import constrain


# --- norms -----------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# --- positions ----------------------------------------------------------------

def rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.exp(-jnp.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    ang = ang[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_pos(positions, d_model: int, dtype=jnp.float32):
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# --- dropout -------------------------------------------------------------------

def dropout(x, rate: float, rng: Optional[jax.Array], deterministic: bool):
    if deterministic or rate == 0.0 or rng is None:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(rng, keep, x.shape)
    return jnp.where(mask, x / keep, jnp.zeros_like(x))


# --- the Galaxy connective block (SP region) ---------------------------------
#
# Paper §III-B-3: Dropout -> Residual Add -> LayerNorm, partitioned along the
# sequence dimension.  In pre-LN architectures the same element-wise ops
# exist as (residual add) here + (the next sub-layer's input norm); the
# ``seq`` constraint below is what makes the exit of the preceding TP block a
# ReduceScatter instead of an AllReduce.

def connective_residual(residual, sublayer_out, rate, rng, deterministic):
    sublayer_out = constrain(sublayer_out, ("batch", "seq", "embed"))
    residual = constrain(residual, ("batch", "seq", "embed"))
    out = residual + dropout(sublayer_out, rate, rng, deterministic)
    return constrain(out, ("batch", "seq", "embed"))


def connective_norm(x, norm_params, norm_kind):
    x = constrain(x, ("batch", "seq", "embed"))
    return constrain(apply_norm(x, norm_params, norm_kind), ("batch", "seq", "embed"))


# --- activations ----------------------------------------------------------------

def activation_fn(name: str):
    return {"gelu": jax.nn.gelu, "silu": jax.nn.silu}.get(name, jax.nn.gelu)
