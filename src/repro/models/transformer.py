"""Model assembly: embeddings -> scanned block groups -> norm -> logits.

The layer stack is executed as ``jax.lax.scan`` over *pattern groups*
(params stacked along a leading group dim) so the lowered HLO is O(1) in
depth — 100-layer models compile as fast as 2-layer ones, which is what
makes the 512-device dry-run tractable on one CPU core.  Remainder layers
(``num_layers % len(pattern)``) run unscanned as "tail" blocks.

Modes: "train" (no cache), "prefill" (build cache), "decode" (one token).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as params_lib
from repro.models.attention import cross_attention_block, self_attention_block
from repro.models.layers import apply_norm, sinusoidal_pos
from repro.models.mlp import mlp_block
from repro.models.moe import moe_block
from repro.models.rglru import rglru_block
from repro.models.sharding import constrain
from repro.models.xlstm import mlstm_block, slstm_block

AUX_KEYS = ("moe_lb_loss", "moe_z_loss", "moe_drop_frac")


def _zero_aux():
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


def _add_aux(a, b):
    return {k: a[k] + b[k] for k in AUX_KEYS}


def _maybe_cast(tree, cfg: ModelConfig):
    target = jnp.dtype(cfg.dtype)
    if jnp.dtype(cfg.param_dtype) == target:
        return tree
    return jax.tree.map(
        lambda w: w.astype(target) if jnp.issubdtype(w.dtype, jnp.floating) else w,
        tree,
    )


def embed_tokens(tok_w, tokens, cfg: ModelConfig):
    # gather from the (vocab, embed)-sharded table; GSPMD materializes the
    # table once per step (cheap vs a (B,S,V) one-hot contraction)
    return tok_w[tokens]


def compute_logits(params, cfg: ModelConfig, x):
    """x: (B,S,d) -> logits (B,S,V) in model dtype (fp32 upcast happens in
    fused loss reductions — a (B,S,150k) fp32 buffer would dominate HBM).
    (B,S,cb,V) for codebook heads."""
    vp = params_lib.padded_vocab(cfg)
    if cfg.tie_embeddings:
        # gather the (vocab/embed)-sharded table before contracting: a 0.3-2.5GB
        # weight AllGather instead of a (B,S,V) logits AllReduce
        w = constrain(_maybe_cast(params["embed"]["tok"], cfg), (None, None))
        logits = jnp.einsum("bsd,vd->bsv", x, w)
        logits = logits[..., None, :]  # cb dim
    else:
        w = constrain(_maybe_cast(params["head"]["w"], cfg), (None, None, None))
        logits = jnp.einsum("bsd,cdv->bscv", x, w)
    if vp != cfg.vocab_size:
        valid = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))
    # sequence stays SP-sharded through the head; vocab replicated per chip
    logits = constrain(logits, ("batch", "seq", None, None))
    if max(1, cfg.num_codebooks) == 1:
        logits = logits[..., 0, :]
    return logits


def _apply_block(
    kind: str,
    p: Dict,
    x,
    cfg: ModelConfig,
    *,
    mode: str,
    cache,
    positions,
    cache_index,
    rng,
    deterministic: bool,
    img_embeds,
):
    aux = _zero_aux()
    if kind == "attn":
        x, new_cache = self_attention_block(
            p, x, cfg, mode=mode, window=cfg.window, cache=cache,
            positions=positions, cache_index=cache_index, rng=rng,
            deterministic=deterministic,
        )
    elif kind == "xattn":
        x, new_cache = cross_attention_block(
            p, x, cfg, mode=mode, img_embeds=img_embeds, cache=cache,
            rng=rng, deterministic=deterministic,
        )
    elif kind == "rec":
        x, new_cache = rglru_block(
            p, x, cfg, mode=mode, cache=cache, rng=rng, deterministic=deterministic
        )
    elif kind == "mlstm":
        x, new_cache = mlstm_block(
            p, x, cfg, mode=mode, cache=cache, rng=rng, deterministic=deterministic
        )
    elif kind == "slstm":
        x, new_cache = slstm_block(
            p, x, cfg, mode=mode, cache=cache, rng=rng, deterministic=deterministic
        )
    else:
        raise ValueError(kind)

    # FFN sub-layer for attention-bearing blocks (rec blocks keep Griffin's MLP)
    if kind in ("attn", "xattn", "rec"):
        if cfg.is_moe:
            x, aux = moe_block(p, x, cfg, rng=rng, deterministic=deterministic)
        elif cfg.d_ff > 0:
            x = mlp_block(p, x, cfg, rng=rng, deterministic=deterministic)
    return x, new_cache, aux


def apply_model(
    params,
    cfg: ModelConfig,
    *,
    tokens=None,
    embeds=None,
    img_embeds=None,
    mode: str = "train",
    cache: Optional[Dict] = None,
    positions=None,
    cache_index=None,
    rng=None,
    deterministic: bool = True,
    unroll: bool = False,
) -> Tuple[jax.Array, Optional[Dict], Dict]:
    """Returns (logits, new_cache, aux).  ``unroll=True`` unrolls the group
    scan (used by the dry-run cost measurement: XLA's cost_analysis counts a
    while-loop body once regardless of trip count)."""
    dtype = jnp.dtype(cfg.dtype)

    if cfg.input_mode == "token":
        x = embed_tokens(_maybe_cast(params["embed"]["tok"], cfg), tokens, cfg).astype(dtype)
        bsz, seq = tokens.shape
    else:
        x = embeds.astype(dtype)
        bsz, seq = embeds.shape[0], embeds.shape[1]

    if mode == "decode" and cache_index is None:
        raise ValueError("decode mode requires cache_index")
    if positions is None:
        if mode == "decode":
            # scalar index (lockstep batch) or (B,) per-slot depths
            ci = jnp.asarray(cache_index, jnp.int32)
            if ci.ndim == 1:
                positions = jnp.broadcast_to(ci[:, None], (bsz, seq))
            else:
                positions = jnp.full((bsz, seq), ci, jnp.int32)
        elif mode == "prefill" and cache_index is not None:
            # chunked prefill: the chunk's rows sit at absolute positions
            # [cache_index, cache_index + seq)
            ci = jnp.asarray(cache_index, jnp.int32)
            positions = ci + jnp.broadcast_to(
                jnp.arange(seq, dtype=jnp.int32), (bsz, seq))
        else:
            positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (bsz, seq))

    if cfg.pos_embedding == "sinusoidal":
        x = x + sinusoidal_pos(positions, cfg.d_model, dtype)
    x = constrain(x, ("batch", "seq", "embed"))

    pattern = cfg.block_pattern
    n_per_group = len(pattern)

    def run_group(x, gparams, gcache, gidx):
        # low-precision serving weights (e.g. fp8) are cast to the compute
        # dtype one layer-group at a time (fused/transient, never resident)
        gparams = _maybe_cast(gparams, cfg)
        new_gcache = {}
        aux = _zero_aux()
        for i, kind in enumerate(pattern):
            key = f"b{i}_{kind}"
            rng_i = jax.random.fold_in(rng, gidx * n_per_group + i) if rng is not None else None
            x, c_new, a = _apply_block(
                kind, gparams[key], x, cfg, mode=mode,
                cache=None if gcache is None else gcache[key],
                positions=positions, cache_index=cache_index, rng=rng_i,
                deterministic=deterministic, img_embeds=img_embeds,
            )
            if c_new is not None:
                new_gcache[key] = c_new
            aux = _add_aux(aux, a)
        return x, new_gcache, aux

    use_cache = mode in ("prefill", "decode")
    has_input_cache = cache is not None  # prefill may allocate its own

    def scan_body(carry, xs):
        x, gidx = carry
        if has_input_cache:
            gp, gc = xs
        else:
            gp, gc = xs, None
        x, new_gc, aux = run_group(x, gp, gc, gidx)
        ys = (new_gc, aux) if use_cache else aux
        return (x, gidx + 1), ys

    body = scan_body
    if cfg.remat and mode == "train" and cfg.remat_policy != "none":
        policy = None  # "full": recompute everything
        if cfg.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(scan_body, policy=policy)

    xs = (params["groups"], cache["groups"]) if has_input_cache else params["groups"]
    (x, _), ys = jax.lax.scan(
        body, (x, jnp.int32(0)), xs, unroll=cfg.num_groups if unroll else 1
    )
    if use_cache:
        new_group_cache, aux_stacked = ys
    else:
        new_group_cache, aux_stacked = None, ys
    aux = {k: jnp.sum(v) for k, v in aux_stacked.items()}

    # tail (remainder) blocks — unscanned
    new_tail_cache = {}
    for i, kind in enumerate(cfg.tail_pattern):
        key = f"t{i}_{kind}"
        rng_i = (
            jax.random.fold_in(rng, cfg.num_groups * n_per_group + i)
            if rng is not None
            else None
        )
        x, c_new, a = _apply_block(
            kind, _maybe_cast(params["tail"][key], cfg), x, cfg, mode=mode,
            cache=None if cache is None else cache["tail"].get(key),
            positions=positions, cache_index=cache_index, rng=rng_i,
            deterministic=deterministic, img_embeds=img_embeds,
        )
        if c_new is not None:
            new_tail_cache[key] = c_new
        aux = _add_aux(aux, a)

    x = constrain(x, ("batch", "seq", "embed"))
    x = apply_norm(x, _maybe_cast(params["final_norm"], cfg), cfg.norm)
    logits = compute_logits(params, cfg, x)

    new_cache = {"groups": new_group_cache, "tail": new_tail_cache} if use_cache else None
    return logits, new_cache, aux
