"""Mixture-of-Experts block (granite-moe, olmoe).

GShard-style capacity-based dispatch/combine expressed as einsums, grouped
into token groups so the dispatch tensors stay small.  Layout under HMP:

* token groups ``g`` are sharded over the data axes ("expert_group"),
* the expert dim ``e`` is sharded over the model axis (expert parallelism),
* dispatch is a local slice, the combine contraction over the sharded
  expert dim produces partial sums whose exit into the seq-sharded
  connective block is the same ReduceScatter every HMP TP block ends with —
  the paper's sync-point structure is preserved for MoE.

Experts are padded to a multiple of the expert-parallel degree; padding
experts get -inf router logits and are never selected.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import connective_norm, connective_residual
from repro.models.sharding import constrain

CAPACITY_FACTOR = 2.0
GROUP_SIZE = 128


def _group_size(total_tokens: int) -> int:
    t = min(GROUP_SIZE, total_tokens)
    while total_tokens % t:
        t -= 1
    return t


def moe_capacity(cfg: ModelConfig, group_tokens: int, capacity_factor: float = 0.0) -> int:
    cf = capacity_factor or cfg.moe_capacity_factor
    c = int(cf * cfg.experts_per_token * group_tokens / cfg.num_experts)
    return max(c, 1)


def moe_apply(p: Dict, x, cfg: ModelConfig, *, capacity_factor: float = 0.0
              ) -> Tuple[jax.Array, Dict]:
    """x: (B, S, d) full-seq (TP region).  Returns (partial-sum out, aux)."""
    b, s, d = x.shape
    e_pad = p["we_up"].shape[0]
    e_real = cfg.num_experts
    k = cfg.experts_per_token

    total = b * s
    t = _group_size(total)
    g = total // t
    xg = x.reshape(g, t, d)
    xg = constrain(xg, ("expert_group", None, "embed"))

    # --- router ---------------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(jnp.float32)
    expert_valid = jnp.arange(e_pad) < e_real
    logits = jnp.where(expert_valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)  # (g, t, e) — for aux loss

    top_vals, top_idx = jax.lax.top_k(logits, k)  # (g, t, k)
    top_w = jax.nn.softmax(top_vals, axis=-1)     # normalized combine weights

    # --- capacity assignment (GShard) ------------------------------------
    cap = moe_capacity(cfg, t, capacity_factor)
    combine = jnp.zeros((g, t, e_pad, cap), jnp.float32)
    counts = jnp.zeros((g, e_pad), jnp.int32)
    for j in range(k):
        oh = jax.nn.one_hot(top_idx[:, :, j], e_pad, dtype=jnp.int32)  # (g,t,e)
        pos = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]
        keep = (pos < cap) & (oh > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap, dtype=jnp.float32)
        combine = combine + top_w[:, :, j, None, None] * oh[..., None] * pos_oh
        counts = counts + jnp.sum(oh, axis=1)
    dispatch = (combine > 0).astype(x.dtype)
    combine = combine.astype(x.dtype)
    dispatch = constrain(dispatch, ("expert_group", None, "experts", None))
    combine = constrain(combine, ("expert_group", None, "experts", None))

    # --- expert FFN (expert-parallel over the model axis) -------------------
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)
    xe = constrain(xe, ("expert_group", "experts", None, None))
    if cfg.activation in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("gecd,edf->gecf", xe, p["we_gate"])) * jnp.einsum(
            "gecd,edf->gecf", xe, p["we_up"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, p["we_up"]))
    h = constrain(h, ("expert_group", "experts", None, None))
    ye = jnp.einsum("gecf,efd->gecd", h, p["we_down"])

    # --- combine: contraction over sharded experts -> partial sums ----------
    out = jnp.einsum("gtec,gecd->gtd", combine, ye)
    out = out.reshape(b, s, d)

    # --- aux losses ------------------------------------------------------
    # load-balance (Switch eq. 4): E * sum_e f_e * p_e over real experts
    top1 = jax.nn.one_hot(top_idx[:, :, 0], e_pad, dtype=jnp.float32)
    f_e = jnp.mean(top1, axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    lb_loss = e_real * jnp.sum(f_e * p_e)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.sum(dispatch.astype(jnp.float32)) / (g * t * k)
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss, "moe_drop_frac": dropped}
    return out, aux


def moe_block(p: Dict, x, cfg: ModelConfig, *, rng, deterministic: bool):
    xn = connective_norm(x, p["ln2"], cfg.norm)
    xg = constrain(xn, ("batch", None, "embed"))  # AllGather: enter TP block
    out, aux = moe_apply(p["moe"], xg, cfg)
    x = connective_residual(x, out, cfg.dropout_rate, rng, deterministic)  # ReduceScatter
    return x, aux
