"""Attention blocks: GQA/MQA self-attention (full-causal or sliding-window)
and cross-attention (VLM image layers), with KV caches for serving.

This is the paper's "MHA block": TP partitions the head dimension (wq/wk/wv
column-split by head, wo row-split), so no synchronization happens inside
self-attention (§III-B-1).  Entry from the seq-sharded connective block is
an AllGather; exit back into it is a ReduceScatter — both materialized by
GSPMD from the sharding constraints here + in layers.connective_*.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_norm, connective_norm, connective_residual, rope
from repro.models.sharding import constrain

NEG_INF = -1e30


def _heads_shardable(cfg: ModelConfig) -> bool:
    """True if the query-head dim divides the model axis — the paper's
    head-wise TP (§III-B-1).  Otherwise attention falls back to the SP
    layout (seq-sharded queries, gathered K/V — the paper's §II-C-2 SP
    pattern), used for 24-head archs on the 16-way mesh."""
    from repro.models.sharding import logical_axis_size

    ax = logical_axis_size("heads")
    return ax <= 1 or cfg.num_heads % ax == 0


def _q_axes(cfg: ModelConfig):
    if _heads_shardable(cfg):
        return ("batch", None, "heads", None)
    return ("batch", "seq", None, None)  # SP-attention fallback


def _project_qkv(p, x, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = constrain(q, _q_axes(cfg))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


def _expand_kv(x, cfg: ModelConfig, heads_axis: bool):
    """Repeat KV heads to the full query-head count so every attention
    einsum is plainly sharded along heads (replicated KV + local repeat —
    no collective).  The kv_seq name stays first so a seq-sharded decode
    cache keeps its layout (flash-decoding) instead of resharding."""
    g = cfg.num_heads // x.shape[2]
    if g > 1:
        x = jnp.repeat(x, g, axis=2)
    if heads_axis:
        x = constrain(x, ("batch", "kv_seq", "heads", None))
    return x


def _gqa_scores(q, k, cfg: ModelConfig):
    """q: (B,S,H,hd), k: (B,L,KV,hd) -> scores (B,H,S,L)."""
    hd = q.shape[-1]
    shardable = _heads_shardable(cfg)
    k = _expand_kv(k, cfg, shardable)
    scores = jnp.einsum("bshd,bthd->bhst", q, k) / jnp.sqrt(hd).astype(q.dtype)
    axes = ("batch", "heads", None, "kv_seq") if shardable else ("batch", None, "seq", "kv_seq")
    return constrain(scores, axes)


def _gqa_output(probs, v, cfg: ModelConfig):
    """probs: (B,H,S,L), v: (B,L,KV,hd) -> (B,S,H,hd)."""
    v = _expand_kv(v, cfg, _heads_shardable(cfg))
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _softmax(scores, mask):
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return probs


def causal_window_mask(q_pos, k_pos, window: int):
    """q_pos: (B,S), k_pos: (B,L) or (L,) -> bool (B,1,S,L)."""
    if k_pos.ndim == 1:
        k_pos = k_pos[None, :]
    m = k_pos[:, None, :] <= q_pos[:, :, None]
    if window > 0:
        m = m & (k_pos[:, None, :] > q_pos[:, :, None] - window)
    m = m & (k_pos[:, None, :] >= 0)
    return m[:, None, :, :]


def _chunked_causal_attention(q, k, v, positions, window: int, cfg: ModelConfig):
    """Query-chunked attention for long prefill: the live score buffer is
    (B, H, chunk, S) instead of (B, H, S, S) — the jnp analogue of the
    flash_attention Pallas kernel's blocking (which replaces this on TPU).
    """
    b, s, h, hd = q.shape
    c = cfg.attn_chunk
    assert s % c == 0
    shardable = _heads_shardable(cfg)
    k = _expand_kv(k, cfg, shardable)
    v = _expand_kv(v, cfg, shardable)
    outs = []
    for i in range(s // c):
        qi = jax.lax.dynamic_slice_in_dim(q, i * c, c, axis=1)
        pos_i = jax.lax.dynamic_slice_in_dim(positions, i * c, c, axis=1)
        scores = jnp.einsum("bshd,bthd->bhst", qi, k) / jnp.sqrt(hd).astype(q.dtype)
        mask = causal_window_mask(pos_i, positions, window)
        probs = _softmax(scores, mask).astype(v.dtype)
        outs.append(jnp.einsum("bhst,bthd->bshd", probs, v))
    return jnp.concatenate(outs, axis=1)


def _window_cache_positions(cache_index, window: int):
    """Token position held in each rolling-buffer slot after the write at
    ``cache_index``: slot s holds t = idx - ((idx - s) mod W); t<0 => empty."""
    slots = jnp.arange(window)
    t = cache_index - jnp.mod(cache_index - slots, window)
    return jnp.where(t >= 0, t, -1)


def init_attn_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> Dict:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, cache_len, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_cache_struct(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, cache_len, kv, hd)
    return {"k": jax.ShapeDtypeStruct(shape, dtype), "v": jax.ShapeDtypeStruct(shape, dtype)}


CACHE_AXES = ("batch", "kv_seq", "kv_heads", None)
XCACHE_AXES = ("batch", "img_seq", "kv_heads", None)


def self_attention_block(
    p: Dict,
    x,
    cfg: ModelConfig,
    *,
    mode: str,
    window: int,
    cache: Optional[Dict],
    positions,
    cache_index,
    rng,
    deterministic: bool,
) -> Tuple[jax.Array, Optional[Dict]]:
    """One MHA sub-layer (norm -> attn -> residual).  Returns (x, new_cache).

    mode: "train" | "prefill" | "decode".
    window: 0 for full causal, >0 for sliding-window (rolling cache).
    positions: (B, S) absolute token positions (rope + causal mask).
    cache_index: write offset into the cache.  Decode: scalar or (B,)
    per-slot depths.  Prefill: None for the one-shot path; a scalar offset
    selects *chunked* prefill — the chunk writes at [offset, offset+S) and
    attends back to the cache's already-filled positions.
    """
    xn = connective_norm(x, p["ln1"], cfg.norm)
    xg = constrain(xn, ("batch", None, "embed"))  # AllGather: enter TP block
    q, k, v = _project_qkv(p, xg, cfg)

    if cfg.pos_embedding == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if mode == "prefill" and cache_index is not None:
        # chunked prefill at an offset (paged serving): write this chunk's
        # K/V at [cache_index, cache_index + S) of the gathered cache view
        # and attend to everything written so far.  Keys beyond the chunk's
        # last position (stale / null-page rows of the page gather) sit at
        # k_pos > max(q_pos) and are causally masked, so they contribute
        # exact zeros — chunked logits equal the one-shot prefill's.
        if window > 0:
            raise ValueError("chunked prefill requires full-causal attention")
        if cache is None:
            raise ValueError("chunked prefill needs the gathered cache view")
        off = jnp.asarray(cache_index, jnp.int32)
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, off, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, off, 0, 0))
        k_cache = constrain(k_cache, CACHE_AXES)
        v_cache = constrain(v_cache, CACHE_AXES)
        new_cache = {"k": k_cache, "v": v_cache}
        mask = causal_window_mask(positions, jnp.arange(k_cache.shape[1]), 0)
        probs = _softmax(_gqa_scores(q, k_cache, cfg), mask)
        out = _gqa_output(probs.astype(v.dtype), v_cache, cfg)
    elif mode in ("train", "prefill"):
        if cfg.attn_chunk and q.shape[1] > cfg.attn_chunk:
            out = _chunked_causal_attention(q, k, v, positions, window, cfg)
        else:
            mask = causal_window_mask(positions, positions, window)
            probs = _softmax(_gqa_scores(q, k, cfg), mask)
            out = _gqa_output(probs.astype(v.dtype), v, cfg)
        if mode == "prefill":
            new_cache = _write_prefill_cache(cfg, cache, k, v, window)
    elif mode == "decode":
        k_cache, v_cache = cache["k"], cache["v"]
        cache_len = k_cache.shape[1]
        per_slot = jnp.ndim(cache_index) == 1  # (B,) per-slot write depths
        if window > 0:
            slot = jnp.mod(cache_index, window)
        else:
            slot = cache_index
        if per_slot:
            rows = jnp.arange(k.shape[0])
            k_cache = k_cache.at[rows, slot].set(k[:, 0])
            v_cache = v_cache.at[rows, slot].set(v[:, 0])
        else:
            k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, slot, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, slot, 0, 0))
        k_cache = constrain(k_cache, CACHE_AXES)
        v_cache = constrain(v_cache, CACHE_AXES)
        new_cache = {"k": k_cache, "v": v_cache}
        if window > 0:
            k_pos = _window_cache_positions(
                cache_index[:, None] if per_slot else cache_index, window)
        elif per_slot:
            span = jnp.arange(cache_len)[None, :]
            k_pos = jnp.where(span <= cache_index[:, None], span, -1)
        else:
            k_pos = jnp.where(jnp.arange(cache_len) <= cache_index,
                              jnp.arange(cache_len), -1)
        mask = causal_window_mask(positions, k_pos, window)
        probs = _softmax(_gqa_scores(q, k_cache, cfg), mask)
        out = _gqa_output(probs.astype(v.dtype), v_cache, cfg)
    else:
        raise ValueError(mode)

    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"])  # row-parallel: partial sums
    x = connective_residual(x, proj, cfg.dropout_rate, rng, deterministic)  # ReduceScatter
    return x, new_cache


def _write_prefill_cache(cfg: ModelConfig, cache: Optional[Dict], k, v, window: int):
    """Fill the cache from prefill K/V.  Full attention: write [0, S).
    Sliding window: keep the last W tokens at slots t % W."""
    b, s = k.shape[0], k.shape[1]
    if cache is None:
        # allocate exactly what prefill produced (engine may re-allocate)
        cache_len = min(s, window) if window > 0 else s
        cache = init_attn_cache(cfg, b, cache_len, k.dtype)
    cache_len = cache["k"].shape[1]
    if window > 0 and s > window:
        keep = window
        k_keep = k[:, -keep:]
        v_keep = v[:, -keep:]
        slots = jnp.mod(jnp.arange(s - keep, s), window)
        k_new = cache["k"].at[:, slots].set(k_keep)
        v_new = cache["v"].at[:, slots].set(v_keep)
    else:
        k_new = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
        v_new = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
    return {"k": constrain(k_new, CACHE_AXES), "v": constrain(v_new, CACHE_AXES)}


def cross_attention_block(
    p: Dict,
    x,
    cfg: ModelConfig,
    *,
    mode: str,
    img_embeds,
    cache: Optional[Dict],
    rng,
    deterministic: bool,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Cross-attention to (stubbed) vision patch embeddings.  The image K/V
    are computed once (prefill/train) and frozen in the cache for decode."""
    xn = connective_norm(x, p["ln1"], cfg.norm)
    xg = constrain(xn, ("batch", None, "embed"))
    q = jnp.einsum("bsd,dhk->bshk", xg, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = constrain(q, ("batch", None, "heads", None))

    if mode == "decode":
        k, v = cache["k"], cache["v"]
        new_cache = cache
    else:
        imgs = apply_norm(img_embeds, p["kv_norm"], cfg.norm)
        k = jnp.einsum("bid,dhk->bihk", imgs, p["wk"])
        v = jnp.einsum("bid,dhk->bihk", imgs, p["wv"])
        if cfg.qkv_bias:
            k = k + p["bk"]
            v = v + p["bv"]
        k = constrain(k, XCACHE_AXES)
        v = constrain(v, XCACHE_AXES)
        new_cache = {"k": k, "v": v} if mode == "prefill" else None

    mask = jnp.ones((1, 1, q.shape[1], k.shape[1]), bool)
    probs = _softmax(_gqa_scores(q, k, cfg), mask)
    out = _gqa_output(probs.astype(v.dtype), v, cfg)
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    proj = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(proj.dtype) * proj
    x = connective_residual(x, proj, cfg.dropout_rate, rng, deterministic)
    return x, new_cache
