"""RG-LRU recurrent block (Griffin / RecurrentGemma).

The recurrence h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t) is
*diagonal*, so TP along the recurrence width introduces no cross-shard
dependencies — the paper's HMP applies cleanly to an attention-free block
(DESIGN.md §4).  The recurrence runs as a parallel associative scan over
the sequence (train/prefill) or a single fused step (decode).

Simplification vs Griffin: the r_t / i_t gates are diagonal (per-channel)
rather than block-diagonal dense — noted in DESIGN.md §2.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import connective_norm, connective_residual
from repro.models.sharding import constrain

RGLRU_C = 8.0


def _causal_conv(u, conv_w, conv_b, conv_state):
    """Depthwise causal temporal conv, width cw.
    u: (B,S,w); conv_w: (cw, w); conv_state: (B, cw-1, w) or None."""
    cw = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    else:
        pad = conv_state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)  # (B, S+cw-1, w)
    out = jnp.zeros_like(u)
    s = u.shape[1]
    for j in range(cw):
        out = out + full[:, j : j + s, :] * conv_w[j]
    new_state = full[:, -(cw - 1) :, :] if cw > 1 else pad
    return out + conv_b, new_state


def _gates(p, u):
    """Diagonal RG-LRU gating. Returns (a, b) of h_t = a⊙h_{t-1} + b (fp32)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(p["gate_a_w"].astype(jnp.float32) * uf + p["gate_a_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(p["gate_x_w"].astype(jnp.float32) * uf + p["gate_x_b"].astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["a_param"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, None)) * (i * uf)
    return a, b


def rglru_scan(a, b, h0: Optional[jax.Array]):
    """Parallel associative scan of h_t = a_t h_{t-1} + b_t along axis 1.
    a, b: (B, S, w) fp32; h0: (B, w) or None. Returns (h_seq, h_last)."""
    if h0 is not None:
        # fold the carry into the first step
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(b.dtype))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1, :]


def init_rec_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    w, cw = cfg.lru_width, cfg.conv_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cw - 1, w), dtype),
    }


def rec_cache_struct(cfg: ModelConfig, batch: int, dtype):
    w, cw = cfg.lru_width, cfg.conv_width
    return {
        "h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cw - 1, w), dtype),
    }


REC_CACHE_AXES = {"h": ("batch", "lru"), "conv": ("batch", None, "lru")}


def rglru_block(
    p: Dict,
    x,
    cfg: ModelConfig,
    *,
    mode: str,
    cache: Optional[Dict],
    rng,
    deterministic: bool,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Griffin recurrent sub-layer: norm -> (gate branch ⊗ conv+RG-LRU branch)
    -> out-proj -> residual.  Returns (x, new_cache)."""
    xn = connective_norm(x, p["ln1"], cfg.norm)
    xg = constrain(xn, ("batch", None, "embed"))  # AllGather: enter TP block

    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xg, p["w_gate_in"]))
    u = jnp.einsum("bsd,dw->bsw", xg, p["w_in"])
    gate = constrain(gate, ("batch", None, "lru"))
    u = constrain(u, ("batch", None, "lru"))

    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)

    a, b = _gates(p, u)
    if mode == "decode":
        h_prev = cache["h"]
        h_last = a[:, 0, :] * h_prev + b[:, 0, :]
        h_seq = h_last[:, None, :]
    else:
        h0 = cache["h"] if cache is not None else None
        h_seq, h_last = rglru_scan(a, b, h0)
    h_seq = constrain(h_seq.astype(x.dtype), ("batch", None, "lru"))

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {
            "h": constrain(h_last, ("batch", "lru")),
            "conv": constrain(new_conv, ("batch", None, "lru")),
        }

    merged = h_seq * gate
    out = jnp.einsum("bsw,wd->bsd", merged, p["w_out"])  # row-parallel: partials
    x = connective_residual(x, out, cfg.dropout_rate, rng, deterministic)  # ReduceScatter
    return x, new_cache
