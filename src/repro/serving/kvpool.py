"""Paged KV pool: host-side page bookkeeping for continuous batching.

The pool owns ``num_pages`` fixed-size KV pages and a block table mapping
(slot, logical page) -> physical page.  The *storage* for the pages lives
with the executor (head-sharded exactly like ``core/hmp.py:make_kv_cache``
for the Galaxy executor, the model-zoo cache pytree for the default
executor); this class only does the allocation arithmetic, so it is pure
numpy and can be property-tested without a device.

Page 0 is the **null page**: it is never handed to a request.  Block-table
rows of idle slots (and the unused tail of every row) point at it, so the
jitted decode step can scatter/gather with fixed shapes — writes from idle
slots land in the null page and reads from it are masked out by the
per-slot length mask.

Admission is reservation-based and therefore deadlock-free: a request is
admitted only if the pool can cover its *worst-case* page count (prompt +
max_new_tokens), but pages are physically allocated lazily (prompt pages at
admission, one page at a time as decode crosses page boundaries).  Freed
pages return to the free list on retirement and are reused by later
admissions.

Pages are **refcounted** so prompt-prefix pages can be shared across
requests (``serving/prefix_cache.py``): ``admit(shared_pages=...)`` attaches
already-filled pages to the front of a slot's row and bumps their refcounts
instead of allocating; ``retire`` decrements, and a page returns to the free
list only when its refcount hits zero.  The prefix cache itself holds
references through ``pin``/``unpin`` (a pinned page survives the retirement
of every slot that used it, staying warm for future hits), and ``check()``
validates the full refcount algebra: every page's refcount equals its
block-table row occurrences across live slots plus its pin count.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

NULL_PAGE = 0


class PoolExhausted(RuntimeError):
    """Raised when an allocation violates its reservation (a scheduler bug)."""


class PagedKVPool:
    """Block-table + free-list bookkeeping over a fixed set of KV pages.

    num_pages:  total physical pages, including the reserved null page 0
    page_size:  positions per page
    num_slots:  decode slots (rows of the block table)
    pages_per_slot: block-table width (max logical pages per request)
    """

    def __init__(self, num_pages: int, page_size: int, num_slots: int,
                 pages_per_slot: int):
        if num_pages < 2:
            raise ValueError("need at least one page beyond the null page")
        if page_size < 1 or num_slots < 1 or pages_per_slot < 1:
            raise ValueError("page_size, num_slots, pages_per_slot must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        self.num_slots = num_slots
        self.pages_per_slot = pages_per_slot
        # LIFO free list, low pages first out (stable for tests)
        self._free: List[int] = list(range(num_pages - 1, NULL_PAGE, -1))
        self.block_table = np.full((num_slots, pages_per_slot), NULL_PAGE, np.int32)
        self._allocated: List[List[int]] = [[] for _ in range(num_slots)]
        self._reserved = np.zeros(num_slots, np.int64)
        self.active = np.zeros(num_slots, bool)
        # per-page reference counts: block-table occurrences + pins
        self.refcount = np.zeros(num_pages, np.int64)
        self._pins = np.zeros(num_pages, np.int64)

    # --- capacity -------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        """Physical pages currently referenced (null page excluded)."""
        return self.num_pages - 1 - len(self._free)

    def occupancy(self) -> float:
        """Fraction of usable pages (null page excluded) currently in use —
        the ``kv_pool_occupancy`` gauge in the engine's metrics registry."""
        return self.used_pages / (self.num_pages - 1)

    @property
    def reserved_backlog(self) -> int:
        """Pages promised to active slots but not yet allocated."""
        return int(sum(
            self._reserved[s] - len(self._allocated[s])
            for s in range(self.num_slots) if self.active[s]
        ))

    @property
    def available(self) -> int:
        """Pages a new admission may reserve against."""
        return self.free_pages - self.reserved_backlog

    def pages_for(self, positions: int) -> int:
        """Pages needed to hold ``positions`` KV entries."""
        return -(-positions // self.page_size)

    def can_admit(self, max_positions: int, shared: int = 0) -> bool:
        """``shared`` prefix pages come from the prefix cache (already
        filled), so only the remainder must be free or reservable."""
        need = self.pages_for(max_positions)
        return need <= self.pages_per_slot and need - shared <= self.available

    def free_slot(self) -> Optional[int]:
        idle = np.flatnonzero(~self.active)
        return int(idle[0]) if idle.size else None

    # --- lifecycle ------------------------------------------------------------
    def _attach(self, slot: int, page: int) -> None:
        row = self._allocated[slot]
        self.block_table[slot, len(row)] = page
        row.append(page)
        self.refcount[page] += 1

    def _take_page(self, slot: int) -> int:
        if not self._free:
            raise PoolExhausted(f"slot {slot}: free list empty")
        page = self._free.pop()
        self._attach(slot, page)
        return page

    def _release(self, page: int) -> bool:
        """Drop one reference; returns True if the page was actually freed."""
        if self.refcount[page] <= 0:
            raise ValueError(f"page {page}: release below zero refcount")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free.append(page)
            return True
        return False

    def admit(self, slot: int, initial_positions: int, max_positions: int,
              shared_pages: Sequence[int] = ()) -> None:
        """Reserve ``pages_for(max_positions)`` and allocate the prompt pages.

        ``shared_pages`` are prefix-cache hits: already-filled physical pages
        that become this slot's leading logical pages.  They are attached by
        refcount bump (no allocation), so admission only needs
        ``pages_for(max_positions) - len(shared_pages)`` reservable pages.
        """
        if self.active[slot]:
            raise ValueError(f"slot {slot} is already active")
        need = self.pages_for(max_positions)
        k = len(shared_pages)
        if need > self.pages_per_slot:
            raise ValueError(
                f"request needs {need} pages, block table holds {self.pages_per_slot}"
            )
        if initial_positions > max_positions:
            raise ValueError("initial_positions exceeds max_positions")
        if k > self.pages_for(initial_positions):
            raise ValueError(
                f"{k} shared prefix pages exceed the prompt's "
                f"{self.pages_for(initial_positions)} pages"
            )
        if any(p == NULL_PAGE or self.refcount[p] <= 0 for p in shared_pages):
            raise ValueError("shared pages must be live non-null pages")
        if need - k > self.available:
            raise PoolExhausted(
                f"admission needs {need - k} new pages, {self.available} available"
            )
        self.active[slot] = True
        self._reserved[slot] = need
        for page in shared_pages:
            self._attach(slot, int(page))
        for _ in range(self.pages_for(initial_positions) - k):
            self._take_page(slot)

    def ensure(self, slot: int, position: int) -> None:
        """Allocate pages (within the reservation) so ``position`` is writable."""
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        while len(self._allocated[slot]) * self.page_size <= position:
            if len(self._allocated[slot]) >= self._reserved[slot]:
                raise PoolExhausted(
                    f"slot {slot}: position {position} exceeds reservation "
                    f"of {int(self._reserved[slot])} pages"
                )
            self._take_page(slot)

    def truncate(self, slot: int, positions: int) -> List[int]:
        """Roll a slot back so it holds exactly ``pages_for(positions)``
        pages, releasing the tail pages (speculative-decoding rejection:
        pages ``ensure``-d for draft tokens the verifier refused).  The
        reservation is untouched — it is a worst-case bound and the slot
        may still grow back to it.  Tail pages are always slot-private
        (they lie beyond the prompt, hence beyond any shared prefix), so
        the refcount release frees them immediately unless pinned.
        Returns the pages released."""
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        keep = self.pages_for(positions)
        row = self._allocated[slot]
        if keep >= len(row):
            return []
        dropped = row[keep:]
        for page in reversed(dropped):
            self._release(page)
        self._allocated[slot] = row[:keep]
        self.block_table[slot, keep:] = NULL_PAGE
        return dropped

    def retire(self, slot: int) -> List[int]:
        """Drop the slot's page references; zero its row.  Returns the pages
        the slot held — each goes back to the free list only if this was its
        last reference (unshared pools: all of them, as before)."""
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        pages = self._allocated[slot]
        for page in reversed(pages):
            self._release(page)
        self._allocated[slot] = []
        self._reserved[slot] = 0
        self.block_table[slot, :] = NULL_PAGE
        self.active[slot] = False
        return pages

    def shared_page_count(self) -> int:
        """Physical pages currently referenced by two or more live slots."""
        counts: dict = {}
        for row in self._allocated:
            for p in row:
                counts[p] = counts.get(p, 0) + 1
        return sum(1 for v in counts.values() if v >= 2)

    # --- external references (prefix cache) -----------------------------------
    def pin(self, page: int) -> None:
        """Add an external (prefix-tree) reference to a live page."""
        if page == NULL_PAGE:
            raise ValueError("cannot pin the null page")
        if self.refcount[page] <= 0:
            raise ValueError(f"page {page}: pin of an unallocated page")
        self.refcount[page] += 1
        self._pins[page] += 1

    def unpin(self, page: int) -> bool:
        """Drop an external reference; returns True if the page was freed."""
        if self._pins[page] <= 0:
            raise ValueError(f"page {page}: unpin without a pin")
        self._pins[page] -= 1
        return self._release(page)

    # --- invariants (tests / sharing admissions) ------------------------------
    def check(self) -> None:
        """Validate the refcount algebra: no page leaked, double-freed, or
        null-aliased, and every refcount equals block-table occurrences
        across live slots plus the prefix-tree pin count.  Raises
        AssertionError explicitly (not via ``assert``) so the guard also
        fires under ``python -O``."""
        def ensure(cond, msg):
            if not cond:
                raise AssertionError(msg)

        held: List[int] = [p for row in self._allocated for p in row]
        ensure(NULL_PAGE not in held, "null page was allocated")
        ensure(NULL_PAGE not in self._free, "null page on the free list")
        ensure(len(set(self._free)) == len(self._free), "free-list duplicate")
        occurrences = np.zeros(self.num_pages, np.int64)
        for p in held:
            occurrences[p] += 1
        expect = occurrences + self._pins
        ensure(np.array_equal(self.refcount, expect),
               f"refcount desync: refcount={self.refcount.tolist()} != "
               f"slots+pins={expect.tolist()}")
        # the satellite invariant: total references == pages held by live
        # slots (with multiplicity) + prefix-tree nodes
        ensure(int(self.refcount.sum()) == len(held) + int(self._pins.sum()),
               "refcount sum != slot holdings + tree pins")
        for p in self._free:
            ensure(self.refcount[p] == 0, f"page {p} free while referenced")
        live = int(np.count_nonzero(self.refcount[1:]))
        ensure(live + len(self._free) == self.num_pages - 1, "page leak")
        for s in range(self.num_slots):
            row = self.block_table[s]
            n = len(self._allocated[s])
            ensure(list(row[:n]) == self._allocated[s], "block table desync")
            ensure(bool(np.all(row[n:] == NULL_PAGE)), "stale block-table tail")
            if not self.active[s]:
                ensure(n == 0 and self._reserved[s] == 0,
                       "idle slot holds pages")
