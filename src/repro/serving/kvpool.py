"""Paged KV pool: host-side page bookkeeping for continuous batching.

The pool owns ``num_pages`` fixed-size KV pages and a block table mapping
(slot, logical page) -> physical page.  The *storage* for the pages lives
with the executor (head-sharded exactly like ``core/hmp.py:make_kv_cache``
for the Galaxy executor, the model-zoo cache pytree for the default
executor); this class only does the allocation arithmetic, so it is pure
numpy and can be property-tested without a device.

Page 0 is the **null page**: it is never handed to a request.  Block-table
rows of idle slots (and the unused tail of every row) point at it, so the
jitted decode step can scatter/gather with fixed shapes — writes from idle
slots land in the null page and reads from it are masked out by the
per-slot length mask.

Admission is reservation-based and therefore deadlock-free: a request is
admitted only if the pool can cover its *worst-case* page count (prompt +
max_new_tokens), but pages are physically allocated lazily (prompt pages at
admission, one page at a time as decode crosses page boundaries).  Freed
pages return to the free list on retirement and are reused by later
admissions.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

NULL_PAGE = 0


class PoolExhausted(RuntimeError):
    """Raised when an allocation violates its reservation (a scheduler bug)."""


class PagedKVPool:
    """Block-table + free-list bookkeeping over a fixed set of KV pages.

    num_pages:  total physical pages, including the reserved null page 0
    page_size:  positions per page
    num_slots:  decode slots (rows of the block table)
    pages_per_slot: block-table width (max logical pages per request)
    """

    def __init__(self, num_pages: int, page_size: int, num_slots: int,
                 pages_per_slot: int):
        if num_pages < 2:
            raise ValueError("need at least one page beyond the null page")
        if page_size < 1 or num_slots < 1 or pages_per_slot < 1:
            raise ValueError("page_size, num_slots, pages_per_slot must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        self.num_slots = num_slots
        self.pages_per_slot = pages_per_slot
        # LIFO free list, low pages first out (stable for tests)
        self._free: List[int] = list(range(num_pages - 1, NULL_PAGE, -1))
        self.block_table = np.full((num_slots, pages_per_slot), NULL_PAGE, np.int32)
        self._allocated: List[List[int]] = [[] for _ in range(num_slots)]
        self._reserved = np.zeros(num_slots, np.int64)
        self.active = np.zeros(num_slots, bool)

    # --- capacity -------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def reserved_backlog(self) -> int:
        """Pages promised to active slots but not yet allocated."""
        return int(sum(
            self._reserved[s] - len(self._allocated[s])
            for s in range(self.num_slots) if self.active[s]
        ))

    @property
    def available(self) -> int:
        """Pages a new admission may reserve against."""
        return self.free_pages - self.reserved_backlog

    def pages_for(self, positions: int) -> int:
        """Pages needed to hold ``positions`` KV entries."""
        return -(-positions // self.page_size)

    def can_admit(self, max_positions: int) -> bool:
        return (self.pages_for(max_positions) <= self.pages_per_slot
                and self.pages_for(max_positions) <= self.available)

    def free_slot(self) -> Optional[int]:
        idle = np.flatnonzero(~self.active)
        return int(idle[0]) if idle.size else None

    # --- lifecycle ------------------------------------------------------------
    def _take_page(self, slot: int) -> int:
        if not self._free:
            raise PoolExhausted(f"slot {slot}: free list empty")
        page = self._free.pop()
        row = self._allocated[slot]
        self.block_table[slot, len(row)] = page
        row.append(page)
        return page

    def admit(self, slot: int, initial_positions: int, max_positions: int) -> None:
        """Reserve ``pages_for(max_positions)`` and allocate the prompt pages."""
        if self.active[slot]:
            raise ValueError(f"slot {slot} is already active")
        need = self.pages_for(max_positions)
        if need > self.pages_per_slot:
            raise ValueError(
                f"request needs {need} pages, block table holds {self.pages_per_slot}"
            )
        if need > self.available:
            raise PoolExhausted(
                f"admission needs {need} pages, {self.available} available"
            )
        if initial_positions > max_positions:
            raise ValueError("initial_positions exceeds max_positions")
        self.active[slot] = True
        self._reserved[slot] = need
        for _ in range(self.pages_for(initial_positions)):
            self._take_page(slot)

    def ensure(self, slot: int, position: int) -> None:
        """Allocate pages (within the reservation) so ``position`` is writable."""
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        while len(self._allocated[slot]) * self.page_size <= position:
            if len(self._allocated[slot]) >= self._reserved[slot]:
                raise PoolExhausted(
                    f"slot {slot}: position {position} exceeds reservation "
                    f"of {int(self._reserved[slot])} pages"
                )
            self._take_page(slot)

    def retire(self, slot: int) -> List[int]:
        """Return the slot's pages to the free list; zero its row."""
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        pages = self._allocated[slot]
        self._free.extend(reversed(pages))
        self._allocated[slot] = []
        self._reserved[slot] = 0
        self.block_table[slot, :] = NULL_PAGE
        self.active[slot] = False
        return pages

    # --- invariants (tests) ---------------------------------------------------
    def check(self) -> None:
        """Assert no page is leaked, double-allocated, or null-aliased."""
        held = [p for row in self._allocated for p in row]
        assert NULL_PAGE not in held, "null page was allocated"
        assert NULL_PAGE not in self._free, "null page on the free list"
        seen = set(held)
        assert len(seen) == len(held), "page double-allocated across slots"
        assert not (seen & set(self._free)), "allocated page also on free list"
        assert len(held) + len(self._free) == self.num_pages - 1, "page leak"
        for s in range(self.num_slots):
            row = self.block_table[s]
            n = len(self._allocated[s])
            assert list(row[:n]) == self._allocated[s], "block table desync"
            assert np.all(row[n:] == NULL_PAGE), "stale block-table tail"
            if not self.active[s]:
                assert n == 0 and self._reserved[s] == 0, "idle slot holds pages"
