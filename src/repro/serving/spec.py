"""Speculative decoding on the heterogeneous mesh.

Decode on the Galaxy mesh is a single-token TP step per output token:
every token pays a full ring of tensor synchronizations that batch-1
decode cannot hide behind compute.  Speculative decoding converts k of
those ring-bound steps into one *chunked paged prefill*: a small draft
model — placed entirely on the fastest device of the cluster
(:func:`place_draft` over the planner's ``DeviceSpec`` capacities) —
proposes ``k`` greedy tokens, and the full mesh verifies all of them in a
single ``prefill_chunk`` call of ``k + 1`` rows (the slot's last emitted
token plus the k proposals) at the slot's current depth.  Logits row
``j`` of that chunk is exactly what non-speculative greedy decode would
have produced at position ``offset + j`` given the accepted history, so:

* accept the longest prefix of proposals matching the per-row argmax
  (:func:`longest_accepted_prefix`);
* the first mismatching row's argmax *is* the non-speculative token —
  emit it as the correction;
* if every proposal matches, the final row yields a bonus token.

Each verify round therefore emits between 1 and k+1 tokens and is
bitwise-pinned to the non-speculative greedy output by construction.
Speculation is greedy-only (``temperature=0``): under sampling the
per-row argmax is no longer the token the sequential path would have
drawn.

Rejected proposals roll back by arithmetic, not recomputation: the KV a
rejected token wrote sits at positions the continuous scheduler never
reads (decode masks keys ``<= position`` and the next chunk overwrites
position ``next_index`` before attending to it), so rollback is just
truncating the slot's block-table row — ``PagedKVPool.truncate`` releases
the over-allocated tail pages through the existing refcount algebra.

The draft side mirrors the target: :class:`SpeculativeDecoder` owns its
own ``PagedKVPool`` + executor storage, prefills each admitted prompt
once, and advances all live slots' proposals as *batched* paged decode
steps on the draft executor.  After an all-accept round the draft's KV
lags the target by one position (the k-th proposal was never fed back),
so the next round replays that one token first — ``gap_tokens`` — before
proposing again.

Expected emitted tokens per round at per-position acceptance ``a`` is
``1 + a + ... + a^k`` (``core/costmodel.spec_expected_tokens``);
``core/simulator.spec_decode_summary``/``choose_spec_k`` price the verify
chunk against the mesh's decode step so the planner can pick ``k``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import DeviceSpec
from repro.serving.kvpool import PagedKVPool


def place_draft(devices: Sequence[DeviceSpec]) -> int:
    """Draft placement: the index of the highest-FLOPS device.

    The draft runs unsharded (no ring, no synchronization), so the only
    placement question is raw single-device speed."""
    if not devices:
        raise ValueError("place_draft needs at least one DeviceSpec")
    return int(max(range(len(devices)), key=lambda i: devices[i].flops))


def longest_accepted_prefix(proposed, verified) -> int:
    """Number of leading positions where the draft matches the verifier."""
    n = 0
    for d, v in zip(proposed, verified):
        if int(d) != int(v):
            break
        n += 1
    return n


class SpeculativeDecoder:
    """Draft-model state for the continuous scheduler.

    Owns the draft executor's paged pool (same page size and block-table
    geometry as the target pool, so position arithmetic is shared) and the
    per-slot draft write positions.  The engine drives it with the same
    slot indices it uses for the target pool."""

    def __init__(self, executor, k: int, *, num_slots: int, page_size: int,
                 pages_per_slot: int, num_pages: int = None):
        if k < 1:
            raise ValueError("spec_k must be >= 1")
        if not getattr(executor, "supports_paged", False):
            raise ValueError("draft executor must implement the paged protocol")
        self.executor = executor
        self.k = k
        self.num_slots = num_slots
        total = num_pages or (1 + num_slots * pages_per_slot)
        self.pool = PagedKVPool(total, page_size, num_slots, pages_per_slot)
        self.storage = executor.make_pool(total, page_size)
        # next position the draft will write, per slot (-1 = idle)
        self._pos = np.full(num_slots, -1, np.int64)

    # --- lifecycle (mirrors the target pool) -------------------------------
    def admit(self, slot: int, tokens: np.ndarray, length: int, *,
              max_positions: int) -> None:
        """One-shot draft prefill of the bucket-padded prompt."""
        s_pad = tokens.shape[1]
        self.pool.admit(slot, initial_positions=s_pad,
                        max_positions=max(s_pad, max_positions))
        block_row = jnp.asarray(self.pool.block_table[slot])
        _, self.storage = self.executor.prefill_paged(
            jnp.asarray(tokens), self.storage, block_row, length=length)
        self._pos[slot] = length

    def retire(self, slot: int) -> None:
        self.pool.retire(slot)
        self._pos[slot] = -1

    def observe(self, slot: int, next_index: int) -> None:
        """Record the verifier's outcome for a slot that keeps decoding.

        Rejection leaves the draft ahead of the accepted history — pull it
        back (the stale entries are rewritten before they are ever read)
        and release the over-allocated tail pages.  An all-accept round
        instead leaves the draft one position *behind* (``gap_tokens``)."""
        self._pos[slot] = min(int(self._pos[slot]), next_index)
        self.pool.truncate(slot, int(self._pos[slot]))

    def gap_tokens(self, slot: int, next_index: int, output: List[int],
                   prompt_len: int) -> List[int]:
        """Already-emitted tokens the draft has not ingested yet (at most
        one: the k-th proposal after an all-accept round)."""
        return [output[p - prompt_len]
                for p in range(int(self._pos[slot]), next_index)]

    # --- proposal ----------------------------------------------------------
    def propose(self, live: Sequence[int], last_tokens: Dict[int, int],
                positions: Dict[int, int], k_eff: Dict[int, int],
                catchup: Dict[int, List[int]]) -> Dict[int, List[int]]:
        """Advance every live slot's draft by ``k_eff[i]`` greedy proposals.

        Runs ``max(catchup + k_eff)`` *batched* paged decode steps on the
        draft executor; slots that finish early (or only catch up) are
        masked to the null page exactly like idle slots in the engine's
        decode step.  Returns the proposed tokens per slot."""
        feeds = {i: list(catchup[i]) + [int(last_tokens[i])] for i in live}
        total = {i: len(catchup[i]) + int(k_eff[i]) for i in live}
        drafts: Dict[int, List[int]] = {i: [] for i in live}
        tok = np.zeros((self.num_slots, 1), np.int32)
        pos = np.zeros(self.num_slots, np.int32)
        for j in range(max(total.values(), default=0)):
            active = [i for i in live if j < total[i]]
            if not active:
                break
            mask = np.zeros(self.num_slots, bool)
            for i in active:
                p = int(positions[i]) - len(catchup[i]) + j
                tok[i, 0] = (feeds[i][j] if j < len(feeds[i])
                             else drafts[i][-1])
                pos[i] = p
                self.pool.ensure(i, p)
                mask[i] = True
            bt = np.where(mask[:, None], self.pool.block_table, 0)
            logits, self.storage = self.executor.decode_paged(
                jnp.asarray(tok), self.storage, jnp.asarray(bt),
                jnp.asarray(np.where(mask, pos, 0)),
            )
            nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
            for i in active:
                if j >= len(catchup[i]):  # a proposal, not a catch-up step
                    drafts[i].append(int(nxt[i]))
        for i in live:
            self._pos[i] = int(positions[i]) + int(k_eff[i])
        return drafts


def run_spec_round(engine, spec: SpeculativeDecoder, slots, live,
                   pool: PagedKVPool, storage):
    """One speculative round over the live slots: draft k proposals per
    slot (batched on the draft executor), verify each slot's proposals in
    one chunked paged prefill on the target executor, emit the accepted
    prefix plus the correction/bonus token, and roll back rejections.

    Returns ``(storage, finished)`` where ``finished`` is the list of
    ``(slot_index, request)`` pairs that completed this round (their pool
    pages are already retired on both sides)."""
    ex = engine.executor
    tr = engine._trace
    tracks = engine._tracks
    drift = engine.drift
    k_eff = {}
    catchup = {}
    last = {}
    posns = {}
    for i in live:
        sl = slots[i]
        remaining = sl.limit - len(sl.req.output)
        # never propose past the budget: the final token of a request is
        # always the verifier's own (correction or bonus) row
        k_eff[i] = max(0, min(spec.k, remaining - 1))
        catchup[i] = spec.gap_tokens(i, sl.next_index, sl.req.output,
                                     len(sl.req.prompt))
        last[i] = sl.last_token
        posns[i] = sl.next_index
    if tr is not None:
        tr.begin("engine", "spec_round", live=len(live))
        tr.begin("engine", "draft_propose")
    drafts = spec.propose(live, last, posns, k_eff, catchup)
    if tr is not None:
        tr.end("engine")

    finished = []
    for i in live:
        sl = slots[i]
        ke = k_eff[i]
        chunk = np.zeros((1, ke + 1), np.int32)
        chunk[0, 0] = sl.last_token
        chunk[0, 1:] = drafts[i][:ke]
        pool.ensure(i, sl.next_index + ke)
        block_row = jnp.asarray(pool.block_table[i])
        if tr is not None:
            tr.begin("engine", "spec_verify", uid=sl.req.uid, k=ke)
        t0 = time.perf_counter() if drift is not None else 0.0
        logits, storage = ex.prefill_chunk(
            jnp.asarray(chunk), storage, block_row,
            offset=sl.next_index, length=sl.next_index + ke + 1,
        )
        toks = np.asarray(engine._sample_positions(logits))[0]  # (ke+1,)
        accepted = longest_accepted_prefix(drafts[i][:ke], toks[:ke])
        if drift is not None:
            # per-position sampling synced the chunk: wall time for free
            drift.observe("spec_verify", time.perf_counter() - t0,
                          rows=ke + 1, context=sl.next_index + ke + 1)
        if tr is not None:
            tr.end("engine", accepted=accepted)

        emitted, done = 0, False
        for j in range(accepted):
            emitted += 1
            if engine._emit(sl.req, int(drafts[i][j]), sl.limit):
                done = True
                break
        if not done:
            emitted += 1
            done = engine._emit(sl.req, int(toks[accepted]), sl.limit)

        st = engine.stats
        st["spec_steps"] += 1
        st["spec_proposed"] += ke
        st["spec_accepted"] += accepted
        # stats["spec_accept_counts"] reads this histogram back as a
        # value-count dict (the facade returns a copy, so observing the
        # histogram is the one write path)
        engine.metrics.histogram("spec_accepted_per_round").observe(accepted)
        st["decode_steps"] += 1
        st["decode_tokens"] += emitted

        new_next = sl.next_index + emitted
        if done:
            pool.retire(i)
            spec.retire(i)
            finished.append((i, sl.req))
        else:
            sl.last_token = int(toks[accepted])
            sl.next_index = new_next
            if accepted < ke:
                if tracks is not None:
                    tracks.event(sl.req.uid, "spec_rollback",
                                 rejected=ke - accepted)
                pool.truncate(i, new_next)
            spec.observe(i, new_next)
    if tr is not None:
        tr.end("engine")  # spec_round
    return storage, finished
