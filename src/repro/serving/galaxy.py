"""Galaxy HMP executor: serve through the paper-exact schedule.

Bridges the wave scheduler (``serving/engine.py``) and the heterogeneity-
aware HMP executor (``core/hmp.py``): prefill runs the full TP/SP + ring
program sequence-sharded over the mesh, decode runs the single-token TP
step against the head-sharded KV cache — both under the same uneven
``ExecPlan`` the planner produced.

Prompts whose length does not divide the mesh are right-padded to the next
multiple (token 0); causal masking keeps all real positions exact, and each
decode step overwrites its own cache slot before attending, so the padded
prefill rows are never read.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import hmp
from repro.core.execplan import ExecPlan


class GalaxyHMPExecutor:
    """Executor protocol (make_cache / prefill / decode) over HMP layers.

    layers: stack of layer params in *reference* layout (init_layer_params);
            padded once here via ``plan.pad_layer_params``.
    embed:  (vocab, d_model) tied embedding / unembedding table.
    """

    def __init__(self, layers: Sequence[Dict], embed, plan: ExecPlan,
                 mesh: Mesh, *, overlap: bool = True):
        self.plan = plan
        self.mesh = mesh
        self.overlap = overlap
        self.layers = [plan.ensure_padded(p) for p in layers]
        self.embed = jnp.asarray(embed)
        self._prefill_fns: Dict = {}
        self._decode_fn = None

    # --- executor protocol ----------------------------------------------------
    def make_cache(self, batch: int, max_len: int) -> List[Dict]:
        # round up so prefill sequence tiles always fit the cache
        cache_len = self.plan.padded_seq(max_len)
        return hmp.make_kv_cache(
            batch, cache_len, len(self.layers), self.mesh, self.plan,
            dtype=self.embed.dtype,
        )

    def prefill(self, tokens, cache):
        b, s = tokens.shape
        key = (b, s)
        if key not in self._prefill_fns:
            s_pad = self.plan.padded_seq(s)
            mesh, plan, overlap = self.mesh, self.plan, self.overlap

            def prefill(layers, embed, tokens, cache):
                tokens = jnp.pad(tokens, ((0, 0), (0, s_pad - s)))
                x = embed[tokens]  # (B, S_pad, d)
                y, cache = hmp.hmp_prefill(
                    layers, x, mesh, cache, plan=plan, overlap=overlap
                )
                logits = y[:, s - 1] @ embed.T
                return logits, cache

            self._prefill_fns[key] = jax.jit(prefill)
        return self._prefill_fns[key](self.layers, self.embed, tokens, cache)

    def decode(self, tokens, cache, index):
        if self._decode_fn is None:
            mesh, plan = self.mesh, self.plan

            def decode(layers, embed, tokens, cache, index):
                x = embed[tokens]  # (B, 1, d)
                y, cache = hmp.hmp_decode(layers, x, mesh, cache, index, plan=plan)
                logits = y[:, -1] @ embed.T
                return logits, cache

            self._decode_fn = jax.jit(decode)
        return self._decode_fn(self.layers, self.embed, tokens, cache, index)
