"""Galaxy HMP executor: serve through the paper-exact schedule.

Bridges the serving engine (``serving/engine.py``) and the heterogeneity-
aware HMP executor (``core/hmp.py``): prefill runs the full TP/SP + ring
program sequence-sharded over the mesh, decode runs the single-token TP
step against the head-sharded KV cache — both under the same uneven
``ExecPlan`` the planner produced.

Both scheduler protocols are implemented.  Wave: ``make_cache`` /
``prefill`` / ``decode`` against a dense per-wave cache.  Paged
(continuous batching): ``make_pool`` / ``prefill_paged`` / ``decode_paged``
against a pool of head-sharded KV pages (``hmp.make_paged_kv_cache``) —
prefill scatters prompt KV straight into this request's pages, decode
gathers each slot's pages through the block table *inside* the shard_map,
so every device only ever touches its own head shard of the pool.
``prefill_chunk`` extends the paged protocol for the engine's shared-prefix
admission flow (lookup -> refcount bump -> suffix-only chunked prefill) and
chunked prefill: a chunk starts at an arbitrary grain-aligned offset and
attends back to the KV pages already holding the shared prefix and earlier
chunks, so a prefix hit pays compute only for the uncached suffix.

Sequence layout is plan-derived: prefill scatters the prompt into the
plan's padded ragged layout (``ExecPlan.seq_layout`` — per-device sequence
tiles at per-device offsets, padded to the straggler's tile), so uneven
*sequence* plans run end to end and no prompt length depends on mesh
divisibility for correctness.  ``prompt_pad_multiple`` (the engine's
padding policy hook) is likewise plan-derived (``ExecPlan.seq_grain``) and
now only buckets prompt lengths to bound the number of compiled prefill
shapes.  K/V are written at absolute positions, and each decode step
overwrites its own cache slot/page entry before attending, so bucket
padding rows are never read.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import hmp
from repro.core.execplan import ExecPlan


class GalaxyHMPExecutor:
    """Executor protocol over HMP layers (wave + paged serving).

    layers: stack of layer params in *reference* layout (init_layer_params);
            padded once here via ``plan.pad_layer_params``.
    embed:  (vocab, d_model) tied embedding / unembedding table.
    compute_backend: overrides the plan's per-shard compute path
            (``execplan.COMPUTE_BACKENDS``): "xla" is the padded dense
            oracle, "pallas" sheds pad-block work in every prefill/decode
            matmul (and the prefill attention) via ``kernels/ops.py``.
    transport / double_buffer: override the plan's ring transport
            (``ring.RING_TRANSPORTS``): "bucketed" ships each ring hop at
            its tile's bucketed row count instead of the straggler pad,
            and ``double_buffer=True`` issues step k+1's exchange before
            step k's GEMM so the wire hides under compute.  Both leave
            results bitwise-identical to the padded ring.
    """

    def __init__(self, layers: Sequence[Dict], embed, plan: ExecPlan,
                 mesh: Mesh, *, overlap: bool = True,
                 compute_backend: Optional[str] = None,
                 transport: Optional[str] = None,
                 double_buffer: Optional[bool] = None):
        if compute_backend is not None:
            plan = plan.with_backend(compute_backend)
        if transport is not None or double_buffer is not None:
            plan = plan.with_transport(transport, double_buffer=double_buffer)
        self.plan = plan
        self.mesh = mesh
        self.overlap = overlap
        self.layers = [plan.ensure_padded(p) for p in layers]
        self.embed = jnp.asarray(embed)
        self._prefill_fns: Dict = {}
        self._decode_fn = None
        self._decode_paged_fn = None

    # --- padding policy -------------------------------------------------------
    @property
    def prompt_pad_multiple(self) -> int:
        """Plan-derived prompt bucketing grain.  The ragged SP layout makes
        any length correct; bucketing only bounds compiled prefill shapes."""
        return self.plan.seq_grain

    # --- observability --------------------------------------------------------
    def wire_stats(self, seq: Optional[int] = None) -> Dict[str, float]:
        """Ring-transport gauges for the engine's metrics registry.

        Prices one full ring rotation of this plan's :class:`RingSchedule`
        at ``seq`` rows (default: one bucketing grain, the smallest shape
        serving ever ships): rows and activation bytes on the wire, and the
        shipped fraction of what padded transport would move.  Static per
        plan — the engine snapshots it once per run."""
        seq = self.plan.seq_grain if seq is None else seq
        rs = self.plan.ring_schedule(seq)
        row_bytes = self.plan.d_model * jnp.dtype(self.embed.dtype).itemsize
        rows = rs.total_wire_rows()
        return {
            "ring_wire_seq": float(seq),
            "ring_wire_rows": float(rows),
            "ring_wire_rows_padded": float(rs.padded_wire_rows()),
            "ring_wire_bytes": float(rows * row_bytes),
            "ring_wire_fraction": float(rs.wire_fraction()),
        }

    # --- wave protocol --------------------------------------------------------
    def make_cache(self, batch: int, max_len: int) -> List[Dict]:
        # cache rows are *absolute* positions (ragged prefill gathers valid
        # rows before writing), so the cache only needs the largest bucketed
        # prompt length — not the plan's padded ragged extent, which for a
        # strongly uneven seq split would over-allocate KV by max(frac)*D
        grain = self.plan.seq_grain
        cache_len = -(-max_len // grain) * grain
        return hmp.make_kv_cache(
            batch, cache_len, len(self.layers), self.mesh, self.plan,
            dtype=self.embed.dtype,
        )

    def prefill(self, tokens, cache, lengths=None):
        """Prefill a wave.  ``lengths`` (B,) gathers each row's last real
        logit when the wave mixes prompt lengths (rows right-padded).

        The prompt is scattered into the plan's padded ragged layout at
        per-device offsets (identity for an equal split of a dividing
        length) and the output gathered back, so uneven sequence tiles and
        non-dividing lengths run exactly."""
        b, s = tokens.shape
        key = (b, s, lengths is not None)
        if key not in self._prefill_fns:
            layout = self.plan.seq_layout(s)
            mesh, plan, overlap = self.mesh, self.plan, self.overlap

            def prefill(layers, embed, tokens, cache, lengths=None):
                tokens = layout.scatter(tokens)  # identity when dense
                x = embed[tokens]  # (B, padded, d)
                y, cache = hmp.hmp_prefill(
                    layers, x, mesh, cache, plan=plan, overlap=overlap, seq=s
                )
                y = layout.gather(y)  # back to real positions
                if lengths is None:
                    logits = y[:, s - 1] @ embed.T
                else:
                    logits = y[jnp.arange(b), lengths - 1] @ embed.T
                return logits, cache

            self._prefill_fns[key] = jax.jit(prefill)
        if lengths is None:
            return self._prefill_fns[key](self.layers, self.embed, tokens, cache)
        return self._prefill_fns[key](
            self.layers, self.embed, tokens, cache, lengths
        )

    def decode(self, tokens, cache, index):
        if self._decode_fn is None:
            mesh, plan = self.mesh, self.plan

            def decode(layers, embed, tokens, cache, index):
                x = embed[tokens]  # (B, 1, d)
                y, cache = hmp.hmp_decode(layers, x, mesh, cache, index, plan=plan)
                logits = y[:, -1] @ embed.T
                return logits, cache

            self._decode_fn = jax.jit(decode)
        return self._decode_fn(self.layers, self.embed, tokens, cache, index)

    # --- paged protocol -------------------------------------------------------
    @property
    def supports_paged(self) -> bool:
        return True

    def make_pool(self, num_pages: int, page_size: int) -> List[Dict]:
        return hmp.make_paged_kv_cache(
            num_pages, page_size, len(self.layers), self.mesh, self.plan,
            dtype=self.embed.dtype,
        )

    def prefill_chunk(self, tokens, pool, block_row, *, offset, length):
        """One chunked-prefill step (batch 1): run a grain-aligned chunk of
        the prompt at absolute positions [offset, offset + S) through the
        Galaxy schedule, attending back to the pages already written by the
        shared prefix and earlier chunks (``hmp_prefill(offset=)`` gathers
        the block row as attention context inside the shard_map).
        Returns ``(logits, pool)`` with *every* chunk row's logits,
        (1, S, V): row ``j`` predicts position ``offset + j + 1``.  Chunked
        prompt prefill reads only the last real prompt token's row;
        speculative verification (``serving/spec.py``) compares all rows
        against the draft proposals."""
        b, s = tokens.shape
        key = ("chunk", s)
        if key not in self._prefill_fns:
            layout = self.plan.seq_layout(s)
            mesh, plan, overlap = self.mesh, self.plan, self.overlap

            # offset/length stay traced scalars: one compiled program per
            # chunk shape, shared by every offset it runs at
            def prefill(layers, embed, tokens, pool, block_row, offset, length):
                tokens = layout.scatter(tokens)  # identity when dense
                x = embed[tokens]  # (1, padded, d)
                y, pool = hmp.hmp_prefill(
                    layers, x, mesh, pool, plan=plan, overlap=overlap,
                    seq=s, block_row=block_row, offset=offset,
                )
                y = layout.gather(y)
                logits = y @ embed.T  # (1, S, V): all chunk rows
                return logits, pool

            self._prefill_fns[key] = jax.jit(prefill, donate_argnums=(3,))
        return self._prefill_fns[key](
            self.layers, self.embed, tokens, pool, block_row,
            jnp.asarray(offset, jnp.int32), jnp.asarray(length, jnp.int32),
        )

    def prefill_paged(self, tokens, pool, block_row, length: int):
        """Prefill one request (batch 1, tokens bucket-padded by the engine)
        writing prompt KV straight into this request's pool pages."""
        b, s = tokens.shape
        key = ("paged", s)
        if key not in self._prefill_fns:
            layout = self.plan.seq_layout(s)
            mesh, plan, overlap = self.mesh, self.plan, self.overlap

            # length stays a traced scalar so every prompt sharing this
            # padded shape reuses one compiled program
            def prefill(layers, embed, tokens, pool, block_row, length):
                tokens = layout.scatter(tokens)  # identity when dense
                x = embed[tokens]  # (1, padded, d)
                y, pool = hmp.hmp_prefill(
                    layers, x, mesh, pool, plan=plan, overlap=overlap,
                    seq=s, block_row=block_row,
                )
                y = layout.gather(y)
                logits = y[:, length - 1] @ embed.T
                return logits, pool

            # donate the pool so the page scatter happens in place
            self._prefill_fns[key] = jax.jit(prefill, donate_argnums=(3,))
        return self._prefill_fns[key](
            self.layers, self.embed, tokens, pool, block_row,
            jnp.asarray(length, jnp.int32),
        )

    def decode_paged(self, tokens, pool, block_table, positions):
        if self._decode_paged_fn is None:
            mesh, plan = self.mesh, self.plan

            def decode(layers, embed, tokens, pool, block_table, positions):
                x = embed[tokens]  # (S, 1, d)
                y, pool = hmp.hmp_decode(
                    layers, x, mesh, pool, positions, plan=plan,
                    block_table=block_table,
                )
                logits = y[:, -1] @ embed.T
                return logits, pool

            self._decode_paged_fn = jax.jit(decode, donate_argnums=(3,))
        return self._decode_paged_fn(
            self.layers, self.embed, tokens, pool, block_table, positions
        )
