"""Serving engine: continuous batching over a paged KV pool, with the wave
scheduler kept as the reference path.

Two schedulers share one engine:

* ``continuous`` (default when the executor implements the paged protocol)
  — a fixed decode batch of ``max_batch`` *slots* over a shared
  :class:`~repro.serving.kvpool.PagedKVPool`.  Requests are admitted from
  the queue the moment a slot frees (respecting pool capacity), prefill
  writes prompt KV straight into pool pages, every decode step advances all
  live slots at their own depths, and finished requests retire per-slot
  (EOS / max-len), returning their pages for reuse.  No slot idles while
  work is queued — the fix for wave-at-a-time decode, where a finished
  request left its batch slot dead until the whole wave drained.
* ``wave`` — batch same-length prompts, prefill together, decode in
  lockstep.  Kept both as the fallback for executors without the paged
  protocol and as the correctness oracle: for greedy sampling the two
  schedulers produce identical tokens, which tests pin on both executors.

Two continuous-scheduler extensions target prompt-heavy edge traffic:

* **Shared-prefix KV cache** (``prefix_cache=True``): admission runs the
  radix-tree lookup of :class:`~repro.serving.prefix_cache.PrefixCache`
  over the prompt, attaches the hit's already-filled pages to the slot by
  *refcount bump* (``PagedKVPool.admit(shared_pages=...)`` — no new
  allocation, pages free only at refcount zero), and prefills **only the
  uncached suffix** (the executor's ``prefill_chunk`` starts at the cached
  offset and attends back to the shared pages).  After prefill the
  request's own full prompt pages are inserted into the tree for later
  requests; retirement decrements refcounts, and under memory pressure the
  tree evicts idle LRU pages.  Decode needs no changes: reads are
  block-table gathers, each slot writes only its own (never shared) tail
  page.
* **Chunked prefill** (``prefill_chunk=N``): instead of stalling every
  live decode slot for a whole long-prompt prefill, admission queues a
  prefill *task* and the main loop interleaves one N-token (grain-rounded)
  chunk per iteration with the decode step, bounding time-to-first-token
  jitter for already-decoding requests.  Chunks attend back to the pages
  earlier chunks wrote, so the math equals the one-shot prefill.

The engine is model-agnostic: it drives an *executor* exposing
``make_cache`` / ``prefill`` / ``decode`` (wave) and, optionally, the paged
protocol ``supports_paged`` / ``make_pool`` / ``prefill_paged`` /
``decode_paged`` (plus ``prefill_chunk`` for the prefix/chunked paths) and
the ``prompt_pad_multiple`` padding policy (1 for the single-device
``TransformerExecutor``; the mesh size for
``serving.galaxy.GalaxyHMPExecutor``, whose SP prefill needs sequence
multiples).  All shape-dependent functions are jitted once per shape bucket
and reused.

Observability (``repro.obs``): every engine owns a
:class:`~repro.obs.metrics.MetricsRegistry` (``engine.metrics``) and the
old hand-rolled stats dict survives as a read/write *facade* over it
(``engine.stats["decode_steps"]`` keeps working; ``engine.reset_stats()``
zeroes the per-run scope while the registry's lifetime scope keeps
accumulating — the fix for counters silently persisting across ``run()``
calls on a reused engine).  Two opt-in hooks add the expensive signals:

* ``tracer=`` (:class:`~repro.obs.trace.Tracer`) records spans for the
  whole request lifecycle — submit → queued → admitted (prefix lookup) →
  each prefill chunk → each decode step / speculative round (rollback) →
  retire — on one track per request plus an engine-loop track, exportable
  as Chrome trace-event JSON.  Tracing never synchronizes the device and a
  run without a tracer executes zero tracing instructions per token
  (gated structurally in ``tests/test_obs.py``).
* ``drift=`` (:class:`~repro.obs.drift.DriftMonitor`) prices each executed
  step with the planner's simulator and histograms measured/simulated —
  the live costmodel-drift signal.  Drift is a diagnostics mode: it adds
  one ``block_until_ready`` per mid-prompt prefill chunk so chunk ratios
  are wall time (decode steps and verify chunks already sync at sampling).

TTFT / inter-token-latency histograms (``ttft_s`` / ``itl_s``) fill from
the same ``record_times`` stamps as before, at retirement — enable
``record_times=True`` to populate them.  Neither hook perturbs sampling:
greedy tokens are bitwise identical with telemetry on or off.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import defaultdict, deque
from collections.abc import MutableMapping
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.sharding import Rules, axis_rules
from repro.models.transformer import apply_model
from repro.obs.drift import DriftMonitor
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import RequestTracks, Tracer
from repro.serving.kvcache import cache_page_size, make_cache, map_cache_leaves
from repro.serving.kvpool import PagedKVPool
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampler import SamplerConfig, sample, sample_positions
from repro.serving.spec import SpeculativeDecoder, run_spec_round


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # perf_counter stamp per emitted token (filled when the engine runs with
    # record_times=True; the microbench derives per-token latency from it)
    token_times: List[float] = dataclasses.field(default_factory=list)
    # perf_counter stamp at submit() (record_times=True); TTFT per request
    # is token_times[0] - submit_time (see benchmarks/run.py:ttft_percentiles)
    submit_time: Optional[float] = None


def _roundup(x: int, m: int) -> int:
    return -(-x // m) * m


class TransformerExecutor:
    """Default executor: the GSPMD model zoo (models/transformer.py)."""

    def __init__(self, params, cfg: ModelConfig, rules: Optional[Rules] = None):
        self.params = params
        self.cfg = cfg
        self.rules = rules
        self._prefill_fns: Dict = {}
        self._decode_fn = None
        self._decode_paged_fn = None

    # --- padding policy ------------------------------------------------------
    @property
    def prompt_pad_multiple(self) -> int:
        """Prompts need no length padding on a single GSPMD program."""
        return 1

    # --- wave protocol -------------------------------------------------------
    def make_cache(self, batch: int, max_len: int):
        return make_cache(self.cfg, batch, max_len, rules=self.rules)

    def prefill(self, tokens, cache, lengths=None):
        """Prefill a batch of prompts.  ``lengths`` (B,) gathers each row's
        last *real* logit when prompts were right-padded to a shared length;
        None keeps the single-length fast path (logits of the last column)."""
        b, s = tokens.shape
        key = (b, s, lengths is not None)
        if key not in self._prefill_fns:
            cfg, rules = self.cfg, self.rules

            def prefill(params, tokens, cache, lengths=None):
                with axis_rules(rules):
                    logits, cache, _ = apply_model(
                        params, cfg, tokens=tokens, mode="prefill", cache=cache
                    )
                if lengths is None:
                    return logits[:, -1], cache
                return logits[jnp.arange(b), lengths - 1], cache

            self._prefill_fns[key] = jax.jit(prefill)
        if lengths is None:
            return self._prefill_fns[key](self.params, tokens, cache)
        return self._prefill_fns[key](self.params, tokens, cache, lengths)

    def decode(self, tokens, cache, index):
        if self._decode_fn is None:
            cfg, rules = self.cfg, self.rules

            def decode(params, tokens, cache, index):
                with axis_rules(rules):
                    logits, cache, _ = apply_model(
                        params, cfg, tokens=tokens, mode="decode",
                        cache=cache, cache_index=index,
                    )
                return logits[:, -1], cache

            self._decode_fn = jax.jit(decode)
        return self._decode_fn(self.params, tokens, cache, index)

    # --- paged protocol ------------------------------------------------------
    @property
    def supports_paged(self) -> bool:
        """Paged serving covers full-causal attention stacks; recurrent and
        sliding-window caches are not position-addressable pages."""
        cfg = self.cfg
        kinds = tuple(cfg.block_pattern) + tuple(cfg.tail_pattern)
        return all(k == "attn" for k in kinds) and cfg.window == 0

    def make_pool(self, num_pages: int, page_size: int):
        """Pool storage: the model-zoo cache pytree with (batch, seq) read as
        (page, in-page slot) — every leaf is (groups?, P, page_size, kv, hd)."""
        return make_cache(self.cfg, num_pages, page_size, rules=self.rules)

    def prefill_paged(self, tokens, pool, block_row, length: int):
        """Prefill one request (batch 1) and scatter its KV into pool pages.

        tokens: (1, S_pad); length: real prompt length (logits are taken at
        ``length - 1``); block_row: (W,) physical pages of this request.
        """
        b, s = tokens.shape
        if b != 1:
            raise ValueError("paged prefill is per-request: batch must be 1")
        key = ("paged", s)
        if key not in self._prefill_fns:
            cfg, rules = self.cfg, self.rules

            # length stays a traced scalar so every prompt sharing this
            # padded shape reuses one compiled program
            def prefill(params, tokens, pool, block_row, length):
                page_size = cache_page_size(pool)
                with axis_rules(rules):
                    dense = make_cache(cfg, 1, s)
                    logits, dense, _ = apply_model(
                        params, cfg, tokens=tokens, mode="prefill", cache=dense
                    )
                pos = jnp.arange(s)
                phys = block_row[pos // page_size]
                within = pos % page_size

                def scatter(leaf, new, grouped):
                    if grouped:
                        return leaf.at[:, phys, within].set(new[:, 0])
                    return leaf.at[phys, within].set(new[0])

                pool = map_cache_leaves(pool, dense, scatter)
                return logits[:, length - 1], pool

            # donate the pool so XLA scatters into the pages in place
            # instead of copying the whole pool every call
            self._prefill_fns[key] = jax.jit(prefill, donate_argnums=(2,))
        return self._prefill_fns[key](
            self.params, tokens, pool, block_row, jnp.asarray(length, jnp.int32)
        )

    def prefill_chunk(self, tokens, pool, block_row, *, offset, length):
        """One chunked-prefill step (batch 1): gather the slot's pages into
        a dense per-request cache view, run the chunk at absolute positions
        [offset, offset + S) attending back to every already-written
        position (earlier chunks and shared prefix pages), and scatter the
        chunk's KV into its pages.  Returns ``(logits, pool)`` where
        ``logits`` holds *every* chunk row, (1, S, V): row ``j`` predicts
        position ``offset + j + 1``.  Chunked prompt prefill reads only the
        last real prompt token's row; speculative verification
        (``serving/spec.py``) compares all rows against the draft.
        """
        b, s = tokens.shape
        if b != 1:
            raise ValueError("paged prefill is per-request: batch must be 1")
        key = ("chunk", s)
        if key not in self._prefill_fns:
            cfg, rules = self.cfg, self.rules

            # offset/length stay traced scalars: one compiled program per
            # chunk shape, shared by every offset it runs at
            def prefill(params, tokens, pool, block_row, offset, length):
                page_size = cache_page_size(pool)
                w = block_row.shape[0]

                def gather(leaf, _, grouped):
                    if grouped:
                        g = leaf[:, block_row]  # (G, W, page, kv, hd)
                        return g.reshape(g.shape[0], 1, w * page_size,
                                         *g.shape[3:])
                    g = leaf[block_row]
                    return g.reshape(1, w * page_size, *g.shape[2:])

                dense = map_cache_leaves(pool, pool, gather)
                with axis_rules(rules):
                    logits, dense, _ = apply_model(
                        params, cfg, tokens=tokens, mode="prefill",
                        cache=dense, cache_index=offset,
                    )
                pos = offset + jnp.arange(s)
                phys = block_row[pos // page_size]
                within = pos % page_size

                def scatter(leaf, new, grouped):
                    if grouped:
                        return leaf.at[:, phys, within].set(new[:, 0, pos])
                    return leaf.at[phys, within].set(new[0, pos])

                pool = map_cache_leaves(pool, dense, scatter)
                return logits, pool

            self._prefill_fns[key] = jax.jit(prefill, donate_argnums=(2,))
        return self._prefill_fns[key](
            self.params, tokens, pool, block_row,
            jnp.asarray(offset, jnp.int32), jnp.asarray(length, jnp.int32),
        )

    def decode_paged(self, tokens, pool, block_table, positions):
        """One continuous-batching step: gather each slot's pages into a
        dense per-slot view, run the single-token model at per-slot depths,
        scatter the new KV entry back into its page."""
        if self._decode_paged_fn is None:
            cfg, rules = self.cfg, self.rules

            def decode(params, tokens, pool, bt, positions):
                page_size = cache_page_size(pool)
                slots, w = bt.shape
                rows = jnp.arange(slots)

                def gather(leaf, _, grouped):
                    if grouped:
                        g = leaf[:, bt]  # (G, S, W, page, kv, hd)
                        return g.reshape(*g.shape[:2], w * page_size, *g.shape[4:])
                    g = leaf[bt]
                    return g.reshape(slots, w * page_size, *g.shape[3:])

                dense = map_cache_leaves(pool, pool, gather)
                with axis_rules(rules):
                    logits, dense, _ = apply_model(
                        params, cfg, tokens=tokens, mode="decode",
                        cache=dense, cache_index=positions,
                    )
                phys = bt[rows, positions // page_size]
                within = positions % page_size

                def scatter(leaf, new, grouped):
                    if grouped:
                        return leaf.at[:, phys, within].set(new[:, rows, positions])
                    return leaf.at[phys, within].set(new[rows, positions])

                pool = map_cache_leaves(pool, dense, scatter)
                return logits[:, -1], pool

            self._decode_paged_fn = jax.jit(decode, donate_argnums=(2,))
        return self._decode_paged_fn(
            self.params, tokens, pool, block_table, positions
        )


@dataclasses.dataclass
class _Slot:
    """Per-slot decode state for the continuous scheduler."""
    req: Request
    last_token: int
    next_index: int   # absolute position the next decode step writes
    limit: int        # min(max_new_tokens, max_len - prompt_len)


@dataclasses.dataclass
class _PrefillTask:
    """An admitted request whose prompt is (still) being prefilled.

    The pool pages are already reserved (and prefix-hit pages attached);
    ``next_off`` is the first absolute position not yet written — it starts
    at the cached prefix length and advances one chunk per step."""
    req: Request
    slot: int
    tokens: np.ndarray  # (1, s_pad) bucket-padded prompt
    s: int              # real prompt length
    s_pad: int
    limit: int
    next_off: int


class EngineStats(MutableMapping):
    """The engine's historical stats dict, as a facade over the registry.

    Every key the flat dict used to hold reads (and, for counters and the
    shared-pages peak, writes) straight through to the
    :class:`~repro.obs.metrics.MetricsRegistry`, so existing callers —
    ``engine.stats["decode_steps"]``, ``stats["prefill_tokens"] += n`` —
    see identical values while the registry stays the single source of
    truth (snapshots, Prometheus export, run-vs-lifetime scoping).

    Derived keys are computed on read: ``spec_acceptance`` from the
    accepted/proposed counters, ``spec_accept_counts`` as the value-count
    view of the ``spec_accepted_per_round`` histogram.
    """

    _COUNTERS = ("prefill_tokens", "decode_steps", "requests",
                 "decode_tokens", "prefill_chunks", "prefix_hits",
                 "cached_prefix_tokens", "spec_steps", "spec_proposed",
                 "spec_accepted")
    _KEYS = _COUNTERS + ("peak_shared_pages", "spec_acceptance",
                         "spec_accept_counts")

    def __init__(self, metrics: MetricsRegistry):
        self._m = metrics
        for k in self._COUNTERS:
            metrics.counter(k)
        metrics.gauge("peak_shared_pages")
        metrics.histogram("spec_accepted_per_round",
                          "draft tokens accepted per speculative round")

    def __getitem__(self, key):
        if key in self._COUNTERS:
            return self._m.counter(key).value
        if key == "peak_shared_pages":
            return int(self._m.gauge(key).value)
        if key == "spec_acceptance":
            proposed = self._m.counter("spec_proposed").value
            return (self._m.counter("spec_accepted").value / proposed
                    if proposed else 0.0)
        if key == "spec_accept_counts":
            return {int(v): n for v, n in sorted(
                self._m.histogram("spec_accepted_per_round")
                .value_counts().items())}
        raise KeyError(key)

    def __setitem__(self, key, value):
        if key in self._COUNTERS:
            self._m.counter(key).set_run(value)
        elif key == "peak_shared_pages":
            self._m.gauge(key).set(int(value))
        else:
            raise TypeError(
                f"stats[{key!r}] is derived from the metrics registry and "
                f"cannot be assigned"
            )

    def __delitem__(self, key):
        raise TypeError("engine stats keys are fixed")

    def __iter__(self):
        return iter(self._KEYS)

    def __len__(self):
        return len(self._KEYS)

    def __repr__(self):
        return repr(dict(self))

    def __eq__(self, other):
        if isinstance(other, (dict, MutableMapping)):
            return dict(self) == dict(other)
        return NotImplemented


class ServingEngine:
    def __init__(
        self,
        params=None,
        cfg: Optional[ModelConfig] = None,
        *,
        executor=None,
        max_batch: int = 8,
        max_len: int = 512,
        sampler: SamplerConfig = SamplerConfig(),
        rules: Optional[Rules] = None,
        rng_seed: int = 0,
        scheduler: str = "auto",
        page_size: int = 16,
        num_pages: Optional[int] = None,
        record_times: bool = False,
        prefix_cache: bool = False,
        prefill_chunk: Optional[int] = None,
        draft_executor=None,
        spec_k: Optional[int] = None,
        tracer: Optional[Tracer] = None,
        drift: Optional[DriftMonitor] = None,
        metrics: Optional[MetricsRegistry] = None,
    ):
        if executor is None:
            if params is None or cfg is None:
                raise ValueError("pass either (params, cfg) or an executor")
            executor = TransformerExecutor(params, cfg, rules)
        elif params is not None or cfg is not None or rules is not None:
            raise ValueError(
                "params/cfg/rules belong to the executor; pass one or the other"
            )
        if scheduler not in ("auto", "continuous", "wave"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1 token")
        if (prefix_cache or prefill_chunk) and not hasattr(
                executor, "prefill_chunk"):
            raise ValueError(
                "prefix caching / chunked prefill need an executor with "
                "the prefill_chunk protocol"
            )
        if (draft_executor is None) != (spec_k is None):
            raise ValueError(
                "speculative decoding needs both draft_executor and spec_k"
            )
        if spec_k is not None:
            if spec_k < 1:
                raise ValueError("spec_k must be >= 1")
            if scheduler == "wave":
                raise ValueError(
                    "speculative decoding requires the continuous scheduler "
                    "(the wave path has no paged pool to verify against)"
                )
            if sampler.temperature != 0.0:
                raise ValueError(
                    "speculative decoding is greedy-only: the verify chunk's "
                    "per-row argmax is the sequential token only at "
                    "temperature=0"
                )
            if not hasattr(executor, "prefill_chunk"):
                raise ValueError(
                    "speculative verification needs the target executor's "
                    "prefill_chunk protocol"
                )
            if not getattr(draft_executor, "supports_paged", False):
                raise ValueError(
                    "draft executor must implement the paged protocol"
                )
        self.executor = executor
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampler = sampler
        self.rng = jax.random.PRNGKey(rng_seed)
        self.scheduler = scheduler
        self.page_size = page_size
        self.num_pages = num_pages
        self.record_times = record_times
        self.prefix_cache = prefix_cache
        self.prefill_chunk = prefill_chunk
        self.draft_executor = draft_executor
        self.spec_k = spec_k
        self.queue: deque = deque()
        # metrics registry is always live (it *is* the stats storage);
        # span tracing and drift pricing are the opt-in hooks
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.stats = EngineStats(self.metrics)
        self.tracer = tracer
        self._trace = tracer if (tracer is not None and tracer.enabled) else None
        self._tracks = (RequestTracks(self._trace)
                        if self._trace is not None else None)
        self.drift = drift
        if drift is not None and drift.registry is None:
            drift.registry = self.metrics
        # post-run introspection (tests / benches / demos)
        self.prefix_stats: Optional[Dict] = None

    def reset_stats(self) -> None:
        """Zero the per-run stats scope (counters, gauges, histograms).

        A reused engine accumulates stats across ``run()`` calls — call
        this between runs to scope ``engine.stats`` /
        ``engine.metrics.snapshot()`` to the next run only.  The lifetime
        scope (``engine.metrics.snapshot(scope="lifetime")``) keeps
        accumulating across resets.
        """
        self.metrics.reset_run()
        self.prefix_stats = None

    # --- request intake ---------------------------------------------------
    def submit(self, req: Request):
        if self.record_times:
            req.submit_time = time.perf_counter()
        self.queue.append(req)
        self.stats["requests"] += 1
        if self._tracks is not None:
            self._tracks.submit(req.uid)
        self.metrics.gauge("queue_depth").set(len(self.queue))

    def run(self) -> List[Request]:
        """Drain the queue; returns all completed requests."""
        mode = self.scheduler
        if mode == "auto":
            mode = ("continuous"
                    if getattr(self.executor, "supports_paged", False) else "wave")
        if mode == "continuous":
            return self._run_continuous()
        if self.spec_k is not None:
            raise ValueError(
                "speculative decoding requires the continuous scheduler, "
                "but this executor only supports the wave path"
            )
        if self.prefix_cache or self.prefill_chunk:
            raise ValueError(
                "prefix caching / chunked prefill belong to the continuous "
                "scheduler (the wave path has no paged pool to share)"
            )
        return self._run_waves()

    # --- shared helpers ---------------------------------------------------
    @property
    def _pad_multiple(self) -> int:
        return getattr(self.executor, "prompt_pad_multiple", 1)

    def _sample(self, logits):
        self.rng, key = jax.random.split(self.rng)
        return sample(logits, key, self.sampler)

    def _sample_positions(self, logits):
        """Per-position sampling for the speculative verify chunk.  Greedy
        (the only mode speculation runs in) consumes no randomness, so the
        RNG split never perturbs token pinning."""
        self.rng, key = jax.random.split(self.rng)
        return sample_positions(logits, key, self.sampler)

    def _emit(self, r: Request, token: int, limit: int) -> bool:
        """Append one token; returns True if the request just finished.

        The per-token hot path: no telemetry calls live here — TTFT/ITL
        histograms fill from the ``token_times`` stamps at retirement
        (:meth:`_retire_obs`), and tracing marks step boundaries, not
        tokens.
        """
        r.output.append(token)
        if self.record_times:
            r.token_times.append(time.perf_counter())
        if (r.eos_id is not None and token == r.eos_id) or len(r.output) >= limit:
            r.done = True
            return True
        return False

    def _retire_obs(self, r: Request, **span_args) -> None:
        """Observability at request completion: close the request's span
        track and fill the latency histograms from its ``record_times``
        stamps (TTFT = first token - submit; ITL = consecutive gaps)."""
        if self._tracks is not None and self._tracks.is_open(r.uid):
            self._tracks.finish(r.uid, tokens=len(r.output), **span_args)
        if r.submit_time is not None and r.token_times:
            self.metrics.histogram(
                "ttft_s", "time to first token (s)",
            ).observe(r.token_times[0] - r.submit_time)
            itl = self.metrics.histogram("itl_s", "inter-token latency (s)")
            ts = r.token_times
            for a, b in zip(ts, ts[1:]):
                itl.observe(b - a)

    def _pool_gauges(self, pool: PagedKVPool) -> None:
        """KV-pool gauges, updated at admission/retirement boundaries (not
        per decode step — occupancy between admissions moves by at most the
        pages the live slots grow into)."""
        m = self.metrics
        used = pool.used_pages
        m.gauge("kv_pages_used").set(used)
        m.gauge("kv_pool_occupancy", "used / usable pool pages").set(
            pool.occupancy())
        m.gauge("kv_pages_peak", "peak pages used").set_max(used)
        m.gauge("kv_shared_pages").set(pool.shared_page_count())

    # --- continuous batching over the paged pool --------------------------
    def _run_continuous(self) -> List[Request]:
        ex = self.executor
        if not getattr(ex, "supports_paged", False):
            raise ValueError(
                "continuous scheduler needs the paged executor protocol"
            )
        ps = self.page_size
        n_slots = self.max_batch
        # prompts pad to lcm(executor multiple, page size): page-boundary
        # padding costs no extra pages (allocation is page-granular anyway)
        # and bounds the number of distinct prefill shapes — one compiled
        # program per page count instead of one per prompt length.  The
        # same grain aligns prefix-cache hits and prefill chunks, so every
        # suffix chunk starts on a compile-shape boundary.
        grain = math.lcm(self._pad_multiple, ps)
        pad_max = _roundup(self.max_len, grain)
        pages_per_slot = pad_max // ps
        total_pages = self.num_pages or (1 + n_slots * pages_per_slot)
        pool = PagedKVPool(total_pages, ps, n_slots, pages_per_slot)
        storage = ex.make_pool(total_pages, ps)
        pcache = PrefixCache(pool, grain=grain) if self.prefix_cache else None
        self.pool = pool  # introspection (tests / benches)
        # telemetry locals: `tr is None` short-circuits every tracing call
        # site below, so a run without a tracer executes zero tracing
        # instructions per token (gated structurally in tests/test_obs.py)
        tr = self._trace
        tracks = self._tracks
        drift = self.drift
        wire_stats = getattr(ex, "wire_stats", None)
        if wire_stats is not None:
            for name, value in wire_stats().items():
                self.metrics.gauge(name).set(value)
        self._pool_gauges(pool)
        spec = None
        if self.spec_k is not None:
            # the draft pool mirrors the target pool's geometry so slot
            # indices and position arithmetic are shared between the two
            spec = SpeculativeDecoder(
                self.draft_executor, self.spec_k, num_slots=n_slots,
                page_size=ps, pages_per_slot=pages_per_slot,
            )
            self.spec = spec  # introspection (tests / benches)
        chunk_tokens = (None if self.prefill_chunk is None
                        else _roundup(self.prefill_chunk, grain))
        slots: List[Optional[_Slot]] = [None] * n_slots
        prefills: deque = deque()  # admitted slots still mid-prefill
        finished: List[Request] = []

        def prefill_step(t: _PrefillTask) -> bool:
            """Advance one chunk; True when the prompt is fully prefilled.

            The final chunk always covers position ``s - 1`` (chunk starts
            are grain-aligned and ``s_pad - s < grain``), so its logits row
            is the last real prompt token's — the first sampled token."""
            nonlocal storage
            off = t.next_off
            size = (t.s_pad - off if chunk_tokens is None
                    else min(chunk_tokens, t.s_pad - off))
            block_row = jnp.asarray(pool.block_table[t.slot])
            chunk = jnp.asarray(t.tokens[:, off:off + size])
            if tr is not None:
                tr.begin("engine", "prefill_chunk", uid=t.req.uid,
                         offset=off, rows=size)
            t0 = time.perf_counter() if drift is not None else 0.0
            if off == 0 and size == t.s_pad:
                # one-shot program (no context gather): the pre-chunking path
                logits, storage = ex.prefill_paged(
                    chunk, storage, block_row, length=t.s)
            else:
                logits, storage = ex.prefill_chunk(
                    chunk, storage, block_row, offset=off, length=t.s)
                # chunk logits carry every row; the sampled first token
                # comes from the last *real* prompt token's row
                logits = logits[:, max(0, min(t.s - 1 - off, size - 1))]
                self.stats["prefill_chunks"] += 1
            if drift is not None:
                # drift is a diagnostics mode: mid-prompt chunks have no
                # natural sync point, so pricing their wall time costs one
                # block_until_ready here (the tracer alone never syncs)
                jax.block_until_ready(logits)
                drift.observe("prefill_chunk", time.perf_counter() - t0,
                              rows=size, context=off + size)
            if tr is not None:
                tr.end("engine")
            # count *computed* prompt tokens: suffix-only under prefix hits
            self.stats["prefill_tokens"] += max(0, min(t.s, off + size) - off)
            t.next_off = off + size
            if t.next_off < t.s_pad:
                return False
            if pcache is not None:
                # publish this prompt's full pages for later admissions
                # (the partial tail page stays slot-private); the refcount
                # algebra is verified at sharing admissions and end of run
                pcache.insert(t.req.prompt, pool.block_table[t.slot])
            tok = int(np.asarray(self._sample(logits))[0])
            if self._emit(t.req, tok, t.limit):
                pool.retire(t.slot)
                finished.append(t.req)
                self._retire_obs(t.req)
                self._pool_gauges(pool)
            else:
                slots[t.slot] = _Slot(t.req, tok, t.s, t.limit)
                if tracks is not None:
                    tracks.phase(t.req.uid, "decode")
                if spec is not None:
                    spec.admit(t.slot, t.tokens, t.s,
                               max_positions=max(t.s_pad, t.s + t.limit))
            return True

        def admit() -> None:
            """Admission: prefix lookup -> shared-page refcount bump ->
            suffix-only prefill (inline, or queued as chunk tasks)."""
            while self.queue:
                slot = pool.free_slot()
                if slot is None:
                    return
                r = self.queue[0]
                s = len(r.prompt)
                limit = min(r.max_new_tokens, self.max_len - s)
                if limit <= 0:  # no room to decode even one token
                    self.queue.popleft()
                    r.done = True
                    finished.append(r)
                    self._retire_obs(r, rejected=True)
                    self.metrics.gauge("queue_depth").set(len(self.queue))
                    continue
                s_pad = _roundup(s, grain)
                max_positions = max(s_pad, s + limit)
                shared: List[int] = []
                cached = 0
                if pcache is not None:
                    if tr is not None:
                        tr.begin("engine", "prefix_lookup", uid=r.uid)
                    shared, cached = pcache.lookup(r.prompt)
                    if tr is not None:
                        tr.end("engine", cached_tokens=cached,
                               shared_pages=len(shared))
                if not pool.can_admit(max_positions, shared=len(shared)):
                    if pcache is not None:
                        need = (pool.pages_for(max_positions) - len(shared)
                                - pool.available)
                        pcache.evict(need)
                        # eviction may have pruned our own match: re-walk
                        shared, cached = pcache.lookup(r.prompt)
                    if not pool.can_admit(max_positions, shared=len(shared)):
                        return
                self.queue.popleft()
                pool.admit(slot, initial_positions=s_pad,
                           max_positions=max_positions, shared_pages=shared)
                self.metrics.gauge("queue_depth").set(len(self.queue))
                if tracks is not None:
                    tracks.phase(r.uid, "prefill", slot=slot,
                                 cached_tokens=cached)
                self._pool_gauges(pool)
                if shared:
                    self.stats["prefix_hits"] += 1
                    self.stats["cached_prefix_tokens"] += cached
                    self.stats["peak_shared_pages"] = max(
                        self.stats["peak_shared_pages"],
                        pool.shared_page_count())
                    pool.check()
                tokens = np.zeros((1, s_pad), np.int32)
                tokens[0, :s] = r.prompt
                task = _PrefillTask(r, slot, tokens, s, s_pad, limit,
                                    next_off=cached)
                if chunk_tokens is None:
                    # no interleaving requested: prefill to completion now
                    while not prefill_step(task):
                        pass
                else:
                    prefills.append(task)

        admit()
        while any(slots) or prefills or self.queue:
            if not any(slots) and not prefills:
                # nothing active and nothing admissible: drop the whole
                # prefix tree (its pins may be what starves the head
                # request) and retry before declaring the pool too small
                if pcache is not None and len(pcache):
                    pcache.evict(total_pages)
                    admit()
                    if any(slots) or prefills:
                        continue
                r = self.queue[0]
                raise RuntimeError(
                    f"request uid={r.uid} (prompt {len(r.prompt)}, "
                    f"max_new {r.max_new_tokens}) cannot fit the pool of "
                    f"{total_pages} pages x {ps}"
                )
            if prefills:
                # one chunk per iteration, interleaved with the decode step
                # below: long prompts no longer stall live decode slots
                if prefill_step(prefills[0]):
                    prefills.popleft()
            live = [i for i, sl in enumerate(slots) if sl is not None]
            if live and spec is not None:
                # speculative round: draft proposes (batched), the target
                # verifies each slot's proposals in one chunk prefill,
                # rejections roll back by block-table truncation
                storage, done = run_spec_round(
                    self, spec, slots, live, pool, storage)
                for i, req in done:
                    slots[i] = None
                    finished.append(req)
                    self._retire_obs(req)
                if done:
                    self._pool_gauges(pool)
            elif live:
                tokens = np.zeros((n_slots, 1), np.int32)
                positions = np.zeros(n_slots, np.int32)
                live_mask = np.zeros(n_slots, bool)
                for i in live:
                    pool.ensure(i, slots[i].next_index)
                    tokens[i, 0] = slots[i].last_token
                    positions[i] = slots[i].next_index
                    live_mask[i] = True
                # non-live rows (idle *or mid-prefill*) decode against the
                # null page: their dummy write must not touch real pages
                bt = np.where(live_mask[:, None], pool.block_table, 0)
                if tr is not None:
                    tr.begin("engine", "decode_step", live=len(live))
                t0 = time.perf_counter() if drift is not None else 0.0
                logits, storage = ex.decode_paged(
                    jnp.asarray(tokens), storage,
                    jnp.asarray(bt), jnp.asarray(positions),
                )
                self.stats["decode_steps"] += 1
                self.stats["decode_tokens"] += len(live)
                toks = np.asarray(self._sample(logits))
                if drift is not None:
                    # sampling already synced the step: measured time is
                    # wall time with no extra block_until_ready
                    drift.observe("decode", time.perf_counter() - t0,
                                  rows=1,
                                  context=int(positions[live].max()) + 1)
                if tr is not None:
                    tr.end("engine")
                retired = False
                for i in live:
                    sl = slots[i]
                    if self._emit(sl.req, int(toks[i]), sl.limit):
                        pool.retire(i)
                        slots[i] = None
                        finished.append(sl.req)
                        self._retire_obs(sl.req)
                        retired = True
                    else:
                        sl.last_token = int(toks[i])
                        sl.next_index += 1
                if retired:
                    self._pool_gauges(pool)
            admit()  # freed slots refill immediately — continuous batching
        # (spec_acceptance is derived on read by the stats facade)
        if pcache is not None:
            pool.check()  # final refcount-algebra validation for the run
            self.prefix_stats = pcache.stats()
            pcache.publish(self.metrics)
        else:
            self.prefix_stats = None
        return finished

    # --- wave execution ------------------------------------------------------
    def _bucket_len(self, prompt_len: int) -> int:
        """Wave bucket key: prompt length rounded up to the executor's
        padding multiple, so e.g. 11- and 12-token prompts share a wave on a
        4-device mesh while a single-device executor buckets exact lengths."""
        return _roundup(prompt_len, self._pad_multiple)

    def _next_wave(self) -> List[Request]:
        """Take up to max_batch queued requests from the largest bucket."""
        if not self.queue:
            return []
        buckets: Dict[int, List[Request]] = defaultdict(list)
        for r in self.queue:
            buckets[self._bucket_len(len(r.prompt))].append(r)
        _, reqs = max(buckets.items(), key=lambda kv: len(kv[1]))
        wave = reqs[: self.max_batch]
        # one-pass rebuild (deque.remove in a loop is O(n^2) and reorders
        # FIFO ties badly under load)
        taken = {id(r) for r in wave}
        self.queue = deque(r for r in self.queue if id(r) not in taken)
        return wave

    def _run_waves(self) -> List[Request]:
        finished: List[Request] = []
        while self.queue:
            wave = self._next_wave()
            if not wave:
                break
            self.metrics.gauge("queue_depth").set(len(self.queue))
            finished.extend(self._run_wave(wave))
        return finished

    def _run_wave(self, wave: List[Request]) -> List[Request]:
        tr = self._trace
        tracks = self._tracks
        # zero-budget requests (max_new_tokens=0, prompt filling or exceeding
        # max_len) never emit and never prefill, matching the continuous
        # path's admission-time retirement — an oversized prompt must not
        # reach the executor, whose cache only holds max_len positions
        for r in wave:
            if min(r.max_new_tokens, self.max_len - len(r.prompt)) <= 0:
                r.done = True
                self._retire_obs(r, rejected=True)
        live = [r for r in wave if not r.done]
        if not live:
            return wave
        b = len(live)
        lengths = np.array([len(r.prompt) for r in live], np.int32)
        limits = np.minimum([r.max_new_tokens for r in live],
                            self.max_len - lengths)
        budget = int(limits.max())
        uniform = int(lengths.min()) == int(lengths.max())
        s_pad = int(lengths[0]) if uniform else self._bucket_len(int(lengths.max()))

        tokens = np.zeros((b, s_pad), np.int32)
        for i, r in enumerate(live):
            tokens[i, : lengths[i]] = r.prompt
        cache = self.executor.make_cache(b, self.max_len)
        if tracks is not None:
            for r in live:
                tracks.phase(r.uid, "prefill", wave=True)
        if tr is not None:
            tr.begin("engine", "wave_prefill", batch=b, s_pad=s_pad)
        if uniform:
            logits, cache = self.executor.prefill(jnp.asarray(tokens), cache)
        else:
            logits, cache = self.executor.prefill(
                jnp.asarray(tokens), cache, lengths=jnp.asarray(lengths)
            )
        if tr is not None:
            tr.end("engine")
        self.stats["prefill_tokens"] += int(lengths.sum())
        if tracks is not None:
            # the wave decodes in lockstep: per-request decode phases open
            # together once the (joint) prefill is dispatched
            for r in live:
                tracks.phase(r.uid, "decode")

        active = np.ones(b, bool)
        for step in range(budget):
            next_tok = self._sample(logits)
            next_np = np.asarray(next_tok)
            for i, r in enumerate(live):
                if not active[i]:
                    continue
                if self._emit(r, int(next_np[i]), int(limits[i])):
                    active[i] = False
                    self._retire_obs(r)
            if not active.any():
                break
            if uniform:
                index = jnp.int32(int(lengths[0]) + step)
            else:
                # clamp retired slots that out-ran their own length budget;
                # their writes land in a dead cache row and are never read
                index = jnp.asarray(
                    np.minimum(lengths + step, self.max_len - 1), jnp.int32
                )
            if tr is not None:
                tr.begin("engine", "decode_step", live=int(active.sum()))
            logits, cache = self.executor.decode(next_tok[:, None], cache, index)
            if tr is not None:
                tr.end("engine")
            self.stats["decode_steps"] += 1
            self.stats["decode_tokens"] += int(active.sum())
        for i, r in enumerate(live):
            r.done = True
            if active[i]:  # safety: budget exhausted before _emit finished it
                self._retire_obs(r)
        return wave
