"""Serving engine: batched prefill + lockstep decode with wave scheduling.

Requests are bucketed by prompt length; a *wave* is a batch of same-length
prompts that prefill together and decode in lockstep (shared cache index).
New requests join at wave boundaries; finished slots free at every step
(per-slot EOS/length tracking), and a wave retires when all slots finish —
a static-batching continuous scheduler, the standard pattern before paged
attention.  All shape-dependent functions are jitted once per (batch,
prompt_len) bucket and reused.

The engine is model-agnostic: it drives an *executor* exposing
``make_cache`` / ``prefill`` / ``decode``.  ``TransformerExecutor`` (default)
runs the production GSPMD model zoo; ``serving.galaxy.GalaxyHMPExecutor``
runs the paper-exact HMP schedule under an uneven ``ExecPlan`` on a
multi-device mesh — same wave scheduler, different parallel program.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.sharding import Rules, axis_rules
from repro.models.transformer import apply_model
from repro.serving.kvcache import make_cache
from repro.serving.sampler import SamplerConfig, sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class TransformerExecutor:
    """Default executor: the GSPMD model zoo (models/transformer.py)."""

    def __init__(self, params, cfg: ModelConfig, rules: Optional[Rules] = None):
        self.params = params
        self.cfg = cfg
        self.rules = rules
        self._prefill_fns: Dict = {}
        self._decode_fn = None

    def make_cache(self, batch: int, max_len: int):
        return make_cache(self.cfg, batch, max_len, rules=self.rules)

    def prefill(self, tokens, cache):
        b, s = tokens.shape
        key = (b, s)
        if key not in self._prefill_fns:
            cfg, rules = self.cfg, self.rules

            def prefill(params, tokens, cache):
                with axis_rules(rules):
                    logits, cache, _ = apply_model(
                        params, cfg, tokens=tokens, mode="prefill", cache=cache
                    )
                return logits[:, -1], cache

            self._prefill_fns[key] = jax.jit(prefill)
        return self._prefill_fns[key](self.params, tokens, cache)

    def decode(self, tokens, cache, index):
        if self._decode_fn is None:
            cfg, rules = self.cfg, self.rules

            def decode(params, tokens, cache, index):
                with axis_rules(rules):
                    logits, cache, _ = apply_model(
                        params, cfg, tokens=tokens, mode="decode",
                        cache=cache, cache_index=index,
                    )
                return logits[:, -1], cache

            self._decode_fn = jax.jit(decode)
        return self._decode_fn(self.params, tokens, cache, index)


class ServingEngine:
    def __init__(
        self,
        params=None,
        cfg: Optional[ModelConfig] = None,
        *,
        executor=None,
        max_batch: int = 8,
        max_len: int = 512,
        sampler: SamplerConfig = SamplerConfig(),
        rules: Optional[Rules] = None,
        rng_seed: int = 0,
    ):
        if executor is None:
            if params is None or cfg is None:
                raise ValueError("pass either (params, cfg) or an executor")
            executor = TransformerExecutor(params, cfg, rules)
        elif params is not None or cfg is not None or rules is not None:
            raise ValueError(
                "params/cfg/rules belong to the executor; pass one or the other"
            )
        self.executor = executor
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampler = sampler
        self.rng = jax.random.PRNGKey(rng_seed)
        self.queue: deque = deque()
        self.stats = {"prefill_tokens": 0, "decode_steps": 0, "requests": 0}

    # --- request intake ---------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)
        self.stats["requests"] += 1

    # --- wave execution ------------------------------------------------------
    def _next_wave(self) -> List[Request]:
        """Take up to max_batch queued requests of the same prompt length."""
        if not self.queue:
            return []
        buckets: Dict[int, List[Request]] = defaultdict(list)
        for r in self.queue:
            buckets[len(r.prompt)].append(r)
        length, reqs = max(buckets.items(), key=lambda kv: len(kv[1]))
        wave = reqs[: self.max_batch]
        # one-pass rebuild (deque.remove in a loop is O(n^2) and reorders
        # FIFO ties badly under load)
        taken = {id(r) for r in wave}
        self.queue = deque(r for r in self.queue if id(r) not in taken)
        return wave

    def run(self) -> List[Request]:
        """Drain the queue; returns all completed requests."""
        finished: List[Request] = []
        while self.queue:
            wave = self._next_wave()
            if not wave:
                break
            finished.extend(self._run_wave(wave))
        return finished

    def _run_wave(self, wave: List[Request]) -> List[Request]:
        b = len(wave)
        s = len(wave[0].prompt)
        assert all(len(r.prompt) == s for r in wave), "wave must share prompt length"
        budget = min(self.max_len - s, max(r.max_new_tokens for r in wave))

        tokens = jnp.asarray(np.array([r.prompt for r in wave], np.int32))
        cache = self.executor.make_cache(b, self.max_len)
        logits, cache = self.executor.prefill(tokens, cache)
        self.stats["prefill_tokens"] += b * s

        active = np.ones(b, bool)
        for step in range(budget):
            self.rng, key = jax.random.split(self.rng)
            next_tok = sample(logits, key, self.sampler)
            next_np = np.asarray(next_tok)
            for i, r in enumerate(wave):
                if not active[i]:
                    continue
                t = int(next_np[i])
                r.output.append(t)
                if (r.eos_id is not None and t == r.eos_id) or len(r.output) >= r.max_new_tokens:
                    r.done = True
                    active[i] = False
            if not active.any():
                break
            index = jnp.int32(s + step)
            logits, cache = self.executor.decode(next_tok[:, None], cache, index)
            self.stats["decode_steps"] += 1
        for r in wave:
            r.done = True
        return wave
