"""Cache pytrees for serving: KV caches (full / sliding-window / cross-attn
image KV) and recurrent states (RG-LRU, mLSTM, sLSTM), mirroring the
grouped-scan parameter structure (leading group dim on 'groups' entries).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.attention import CACHE_AXES, XCACHE_AXES
from repro.models.rglru import REC_CACHE_AXES
from repro.models.sharding import Rules
from repro.models.xlstm import MLSTM_CACHE_AXES, SLSTM_CACHE_AXES


def _attn_shapes(cfg: ModelConfig, batch: int, cache_len: int):
    w = cfg.window
    length = w if w > 0 else cache_len  # rolling buffer is always W slots
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, length, kv, hd)
    return {"k": (shape, cfg.dtype), "v": (shape, cfg.dtype)}, CACHE_AXES


def _xattn_shapes(cfg: ModelConfig, batch: int, cache_len: int):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, cfg.num_image_tokens, kv, hd)
    return {"k": (shape, cfg.dtype), "v": (shape, cfg.dtype)}, XCACHE_AXES


def _rec_shapes(cfg: ModelConfig, batch: int, cache_len: int):
    w, cw = cfg.lru_width, cfg.conv_width
    shapes = {
        "h": ((batch, w), "float32"),
        "conv": ((batch, cw - 1, w), cfg.dtype),
    }
    return shapes, REC_CACHE_AXES


def _mlstm_shapes(cfg: ModelConfig, batch: int, cache_len: int):
    di = int(cfg.d_model * cfg.proj_factor)
    nh = cfg.num_heads
    dh = di // nh
    shapes = {
        "c": ((batch, nh, dh, dh), "float32"),
        "n": ((batch, nh, dh), "float32"),
        "m": ((batch, nh), "float32"),
    }
    return shapes, MLSTM_CACHE_AXES


def _slstm_shapes(cfg: ModelConfig, batch: int, cache_len: int):
    di = int(cfg.d_model * cfg.proj_factor)
    nh = cfg.num_heads
    dh = di // nh
    shapes = {k: ((batch, nh, dh), "float32") for k in ("h", "c", "n", "m")}
    return shapes, SLSTM_CACHE_AXES


_SHAPES = {
    "attn": _attn_shapes,
    "xattn": _xattn_shapes,
    "rec": _rec_shapes,
    "mlstm": _mlstm_shapes,
    "slstm": _slstm_shapes,
}

_INIT_SPECIAL = {("mlstm", "m"): -1e30, ("slstm", "m"): -1e30, ("slstm", "n"): 1e-6}


def _make_block_cache(
    cfg, kind: str, batch: int, cache_len: int, *, groups: int,
    abstract: bool, rules: Optional[Rules],
):
    shapes, axes = _SHAPES[kind](cfg, batch, cache_len)
    out = {}
    for name, (shape, dtype) in shapes.items():
        if groups:
            shape = (groups,) + shape
        dt = jnp.dtype(dtype)
        if abstract:
            sharding = None
            if rules is not None and rules.mesh is not None:
                ax = axes[name] if isinstance(axes, dict) else axes
                ax = ((None,) + tuple(ax)) if groups else tuple(ax)
                sharding = jax.sharding.NamedSharding(
                    rules.mesh, rules.spec(ax, shape=shape)
                )
            out[name] = jax.ShapeDtypeStruct(shape, dt, sharding=sharding)
        else:
            fill = _INIT_SPECIAL.get((kind, name), 0.0)
            out[name] = jnp.full(shape, fill, dt)
    return out


def make_cache(
    cfg: ModelConfig,
    batch: int,
    cache_len: int,
    *,
    abstract: bool = False,
    rules: Optional[Rules] = None,
) -> Dict:
    """Build the full cache pytree for `apply_model(mode='decode'|'prefill')`."""
    g = cfg.num_groups
    cache: Dict = {"groups": {}, "tail": {}}
    for i, kind in enumerate(cfg.block_pattern):
        cache["groups"][f"b{i}_{kind}"] = _make_block_cache(
            cfg, kind, batch, cache_len, groups=g, abstract=abstract, rules=rules
        )
    for i, kind in enumerate(cfg.tail_pattern):
        cache["tail"][f"t{i}_{kind}"] = _make_block_cache(
            cfg, kind, batch, cache_len, groups=0, abstract=abstract, rules=rules
        )
    return cache


def cache_page_size(pool: Dict) -> int:
    """Positions per page of a pool built by ``make_cache(num_pages,
    page_size)`` — the (batch, seq) axes of this module's cache layout read
    as (page, in-page slot) under the paged serving protocol."""
    leaf = jax.tree.leaves(pool)[0]
    return leaf.shape[2] if leaf.ndim == 5 else leaf.shape[1]


def map_cache_leaves(pool: Dict, other: Dict, fn) -> Dict:
    """Apply ``fn(pool_leaf, other_leaf, grouped)`` over an attn-only cache
    pytree ({"groups": {...}, "tail": {...}} of {"k","v"} leaves) — grouped
    leaves carry the leading scan-group dim.  This walk owns the schema of
    ``make_cache`` so paged gather/scatter code stays layout-agnostic."""
    out: Dict = {"groups": {}, "tail": {}}
    for key, leaf in pool["groups"].items():
        out["groups"][key] = {
            n: fn(leaf[n], other["groups"][key][n], True) for n in leaf
        }
    for key, leaf in pool["tail"].items():
        out["tail"][key] = {
            n: fn(leaf[n], other["tail"][key][n], False) for n in leaf
        }
    return out


def cache_bytes(cfg: ModelConfig, batch: int, cache_len: int) -> int:
    tree = make_cache(cfg, batch, cache_len, abstract=True)
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree)
    )
