from repro.serving.engine import Request, ServingEngine
from repro.serving.kvcache import cache_bytes, make_cache
from repro.serving.sampler import SamplerConfig, sample

__all__ = ["Request", "ServingEngine", "make_cache", "cache_bytes", "SamplerConfig", "sample"]
