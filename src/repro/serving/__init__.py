from repro.serving.engine import Request, ServingEngine, TransformerExecutor
from repro.serving.galaxy import GalaxyHMPExecutor
from repro.serving.kvcache import cache_bytes, make_cache
from repro.serving.kvpool import PagedKVPool, PoolExhausted
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampler import SamplerConfig, sample, sample_positions
from repro.serving.spec import (
    SpeculativeDecoder, longest_accepted_prefix, place_draft,
)

__all__ = [
    "Request", "ServingEngine", "TransformerExecutor", "GalaxyHMPExecutor",
    "PagedKVPool", "PoolExhausted", "PrefixCache",
    "make_cache", "cache_bytes", "SamplerConfig", "sample", "sample_positions",
    "SpeculativeDecoder", "longest_accepted_prefix", "place_draft",
]
