"""Shared-prefix KV cache: a radix tree over token-id page keys that maps
common prompt prefixes to shared physical pages of a :class:`PagedKVPool`.

Edge serving traffic is dominated by requests sharing long prompt prefixes
(voice-assistant system prompts, few-shot headers).  Recomputing and
duplicating their KV per slot wastes exactly the memory and compute the
in-situ setting is short of, so the cache lets every request that shares a
page-aligned token prefix map its leading logical pages to the *same*
physical pages:

* **Tree shape.**  Each node is one full page: a key of ``page_size`` token
  ids plus the physical page holding that page's KV.  A path from the root
  spells out a prompt prefix page by page, so lookup is a chunk-wise radix
  walk — O(prefix pages), independent of how many prompts are cached.
* **Refcounts.**  The tree itself holds one reference per node
  (``pool.pin``), and every slot using a shared page holds another
  (``admit(shared_pages=...)``).  A page returns to the free list only when
  the last reference drops, so cached prefixes survive the requests that
  created them and serve future hits warm.
* **Granularity / copy-on-write.**  Sharing is page-granular: only pages
  fully covered by real prompt tokens enter the tree, and a lookup is
  floored to the caller's alignment grain.  The partial tail page — the one
  a slot keeps appending decode KV into — is never shared; a request whose
  prefix ends mid-page simply recomputes that page into a private copy
  (copy-on-write by recompute: cheaper than a device-side page copy at edge
  page sizes, and the only mutable page stays slot-private, which is why
  decode needs no locking — reads are block-table gathers, each slot writes
  only its own tail page).
* **Admission flow** (driven by ``serving/engine.py``): ``lookup`` the
  prompt → ``pool.admit`` with the hit pages (refcount bump, no allocation)
  → chunked prefill over only the uncached *suffix* → ``insert`` the
  request's newly written full pages so later requests can hit them.
* **Eviction.**  When admission runs out of reservable pages, ``evict``
  unpins least-recently-used *leaves* whose page is held by the tree alone
  (never pages a live slot still reads), cascading up the path while that
  frees capacity.

Pure numpy/python like the pool — property-testable without a device.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.kvpool import PagedKVPool


@dataclasses.dataclass
class _Node:
    """One cached page: ``key`` is its page_size-token content, ``page`` the
    physical page holding its KV.  Children extend the prefix by one page."""
    key: Tuple[int, ...]
    page: int
    parent: Optional["_Node"]
    children: Dict[Tuple[int, ...], "_Node"] = dataclasses.field(
        default_factory=dict)
    last_used: int = 0


class PrefixCache:
    """Radix-tree prefix index over a :class:`PagedKVPool`.

    grain: alignment of reusable prefix lengths in tokens (the serving
    engine passes its prefill bucketing grain — a multiple of ``page_size``
    — so suffix prefill always starts on a compile-shape boundary).
    """

    def __init__(self, pool: PagedKVPool, grain: Optional[int] = None):
        self.pool = pool
        self.page_size = pool.page_size
        grain = pool.page_size if grain is None else grain
        if grain % pool.page_size:
            raise ValueError(
                f"grain {grain} must be a multiple of page_size {pool.page_size}"
            )
        self.grain = grain
        self._root = _Node(key=(), page=-1, parent=None)
        self._clock = 0
        self._n_nodes = 0
        self._stats = {"lookups": 0, "hits": 0, "hit_tokens": 0,
                       "inserted_pages": 0, "evicted_pages": 0}

    # --- queries --------------------------------------------------------------
    def __len__(self) -> int:
        return self._n_nodes

    def _chunks(self, tokens: Sequence[int]):
        ps = self.page_size
        for j in range(len(tokens) // ps):
            yield tuple(int(t) for t in tokens[j * ps:(j + 1) * ps])

    def lookup(self, prompt: Sequence[int]) -> Tuple[List[int], int]:
        """Longest cached page-aligned proper prefix of ``prompt``.

        Returns ``(pages, cached_len)``: the shared physical pages covering
        the prefix and its token length — floored to the alignment grain and
        capped at ``len(prompt) - 1`` so at least one suffix token is always
        computed (prefill must produce the last-token logits).
        """
        self._clock += 1
        self._stats["lookups"] += 1
        node = self._root
        matched: List[_Node] = []
        for key in self._chunks(prompt):
            child = node.children.get(key)
            if child is None:
                break
            child.last_used = self._clock
            matched.append(child)
            node = child
        limit = len(prompt) - 1
        cached = min(len(matched) * self.page_size, max(limit, 0))
        cached = (cached // self.grain) * self.grain
        pages = [n.page for n in matched[: cached // self.page_size]]
        if pages:
            self._stats["hits"] += 1
            self._stats["hit_tokens"] += cached
        return pages, cached

    # --- growth ---------------------------------------------------------------
    def insert(self, prompt: Sequence[int], block_row: Sequence[int]) -> int:
        """Publish a prefilled request's full prompt pages into the tree.

        ``block_row``: the slot's physical pages (leading entries cover the
        prompt).  Only pages fully covered by real prompt tokens are
        insertable — the partial tail page stays slot-private.  Pages whose
        path already exists are skipped (the first request to finish a
        prefix wins; duplicates stay private to their slot).  Returns the
        number of pages newly pinned into the tree.
        """
        self._clock += 1
        node = self._root
        added = 0
        for j, key in enumerate(self._chunks(prompt)):
            child = node.children.get(key)
            if child is None:
                page = int(block_row[j])
                self.pool.pin(page)
                child = _Node(key=key, page=page, parent=node,
                              last_used=self._clock)
                node.children[key] = child
                self._n_nodes += 1
                added += 1
            else:
                child.last_used = self._clock
            node = child
        self._stats["inserted_pages"] += added
        return added

    # --- shrinkage ------------------------------------------------------------
    def _leaves(self) -> List[_Node]:
        out: List[_Node] = []
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    def _drop(self, node: _Node) -> bool:
        """Remove a leaf from the tree; returns True if its page was freed."""
        assert not node.children
        del node.parent.children[node.key]
        self._n_nodes -= 1
        return self.pool.unpin(node.page)

    def evict(self, need_pages: int) -> int:
        """Free up to ``need_pages`` by unpinning LRU leaves whose page is
        held by the tree alone (refcount 1 — no live slot reads it),
        cascading into parents as they become evictable leaves.  Returns
        the number of pages actually freed."""
        freed = 0
        while freed < need_pages:
            idle = [n for n in self._leaves()
                    if self.pool.refcount[n.page] == 1]
            if not idle:
                break
            victim = min(idle, key=lambda n: n.last_used)
            if self._drop(victim):
                freed += 1
                self._stats["evicted_pages"] += 1
        return freed

    def clear(self) -> int:
        """Unpin every node (teardown); returns pages freed."""
        freed = 0
        while self._n_nodes:
            for leaf in self._leaves():
                if self._drop(leaf):
                    freed += 1
                    self._stats["evicted_pages"] += 1
        return freed

    # --- introspection --------------------------------------------------------
    def held_pages(self) -> List[int]:
        """Physical pages currently pinned by tree nodes."""
        out: List[int] = []
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            out.append(n.page)
            stack.extend(n.children.values())
        return out

    def stats(self) -> Dict[str, float]:
        s = dict(self._stats)
        s["nodes"] = self._n_nodes
        s["hit_rate"] = (s["hits"] / s["lookups"]) if s["lookups"] else 0.0
        return s

    def publish(self, registry) -> None:
        """Mirror :meth:`stats` into a ``repro.obs.MetricsRegistry`` (the
        serving engine calls this at end of run)."""
        s = self.stats()
        registry.gauge(
            "prefix_hit_rate", "prefix-cache hits / lookups").set(s["hit_rate"])
        registry.gauge(
            "prefix_nodes", "radix-tree nodes (one full page each)",
        ).set(s["nodes"])
        registry.gauge(
            "prefix_hit_tokens", "prompt tokens served from shared pages",
        ).set(s["hit_tokens"])
