"""Token samplers (greedy / temperature / top-k), vocab-sharding friendly:
everything is argmax/reductions over the (possibly sharded) vocab axis."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0   # 0 = greedy
    top_k: int = 0             # 0 = full distribution


def sample(logits, rng, cfg: SamplerConfig):
    """logits: (B, V) fp32 -> (B,) int32."""
    if cfg.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        vals, _ = jax.lax.top_k(logits, cfg.top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits >= cutoff, logits, -1e30)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)


def sample_positions(logits, rng, cfg: SamplerConfig):
    """Sample every position of a (B, K, V) logits block -> (B, K) int32.

    The speculative verify step scores all K draft positions in one chunk
    prefill and needs a token per position.  Each position draws from its
    own split of ``rng`` so the stream matches K sequential ``sample``
    calls in distribution; at ``temperature == 0`` this reduces exactly to
    per-position argmax (no RNG consumed), which is what pins speculative
    greedy output to the non-speculative path."""
    b, k, v = logits.shape
    if cfg.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    keys = jax.random.split(rng, k)
    cols = [sample(logits[:, j], keys[j], cfg) for j in range(k)]
    return jnp.stack(cols, axis=1)
