"""Token samplers (greedy / temperature / top-k), vocab-sharding friendly:
everything is argmax/reductions over the (possibly sharded) vocab axis."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0   # 0 = greedy
    top_k: int = 0             # 0 = full distribution


def sample(logits, rng, cfg: SamplerConfig):
    """logits: (B, V) fp32 -> (B,) int32."""
    if cfg.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        vals, _ = jax.lax.top_k(logits, cfg.top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits >= cutoff, logits, -1e30)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
