"""Metrics registry: counters / gauges / histograms for the serving stack.

One registry per :class:`~repro.serving.engine.ServingEngine` is the source
of truth for everything the engine used to keep in its hand-rolled stats
dict (the dict survives as a read/write *facade* over the registry, so
``engine.stats["decode_steps"]`` keeps working).  Three instrument kinds:

* :class:`Counter` — monotone within a scope (`prefill_tokens`,
  `decode_steps`, `spec_proposed`, ...).
* :class:`Gauge` — last-set value (`queue_depth`, `kv_pool_occupancy`,
  `prefix_hit_rate`), with :meth:`Gauge.set_max` for peak tracking.
* :class:`Histogram` — full-sample histogram with nearest-rank percentiles
  (`ttft_s`, `itl_s`, `spec_accepted_per_round`, `sim_drift_ratio/*`).
  Samples are kept (serving runs observe thousands, not billions), so any
  percentile is exact.

Every instrument carries **two scopes**: the *run* scope, zeroed by
:meth:`MetricsRegistry.reset_run` (``ServingEngine.reset_stats``), and the
*lifetime* scope, which survives resets — so a reused engine can report
"this run" and "since construction" separately instead of silently
accumulating across runs (the old stats-dict bug).

Export: :meth:`MetricsRegistry.snapshot` returns a plain nested dict
(counters / gauges / histogram summaries) and
:meth:`MetricsRegistry.to_prometheus` renders the Prometheus text
exposition format (counters as ``_total``, histograms as summaries with
``quantile`` labels).

The module also owns the one shared latency-percentile helper family —
:func:`percentile` / :func:`percentile_summary` / :func:`ttft_seconds` /
:func:`itl_seconds` / :func:`ttft_percentiles` — that
``benchmarks/run.py`` and ``benchmarks/microbench.py`` previously each
re-derived from raw ``Request.token_times`` stamp lists.

Pure python (no jax, no numpy): importable everywhere, including the
host-side bookkeeping paths that must stay allocation-free when telemetry
is off.
"""
from __future__ import annotations

import re
from typing import Dict, Iterable, List, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "percentile", "percentile_summary",
    "ttft_seconds", "itl_seconds", "ttft_percentiles",
]


# --- shared percentile helpers (benchmarks/run.py + microbench.py) ------------

def percentile(values: Sequence[float], p: float) -> float:
    """Nearest-rank percentile on an (unsorted) sample; nan when empty.

    The one percentile definition shared by the registry's histograms, the
    TTFT rows in ``benchmarks/run.py`` and the ITL rows in
    ``benchmarks/microbench.py`` — previously each derived its own.
    """
    if not values:
        return float("nan")
    xs = sorted(values)
    k = min(len(xs) - 1, max(0, int(round(p / 100 * (len(xs) - 1)))))
    return xs[k]


def percentile_summary(values: Sequence[float],
                       ps: Iterable[float] = (50, 95, 99)) -> Dict[str, float]:
    """``{"p50": ..., "p95": ..., ...}`` plus count/sum/min/max."""
    out: Dict[str, float] = {"n": len(values)}
    xs = sorted(values)
    for p in ps:
        key = f"p{p:g}"
        if not xs:
            out[key] = float("nan")
        else:
            k = min(len(xs) - 1, max(0, int(round(p / 100 * (len(xs) - 1)))))
            out[key] = xs[k]
    out["sum"] = float(sum(xs)) if xs else 0.0
    out["min"] = xs[0] if xs else float("nan")
    out["max"] = xs[-1] if xs else float("nan")
    return out


def ttft_seconds(requests) -> List[float]:
    """Per-request time-to-first-token samples from the engine's
    ``record_times`` stamps (``token_times[0] - submit_time``).  Requests
    that emitted nothing (or ran without stamps) are skipped."""
    return [
        r.token_times[0] - r.submit_time
        for r in requests
        if r.token_times and r.submit_time is not None
    ]


def itl_seconds(requests) -> List[float]:
    """Inter-token latency samples: consecutive ``token_times`` gaps across
    all requests (a request with one token contributes none)."""
    out: List[float] = []
    for r in requests:
        ts = r.token_times
        out.extend(b - a for a, b in zip(ts, ts[1:]))
    return out


def ttft_percentiles(requests) -> Dict[str, float]:
    """TTFT p50/p95 summary in the shape ``benchmarks/run.py`` always
    reported: ``{"p50": s, "p95": s, "n": count}`` (seconds)."""
    ttfts = ttft_seconds(requests)
    return {"p50": percentile(ttfts, 50), "p95": percentile(ttfts, 95),
            "n": len(ttfts)}


# --- instruments --------------------------------------------------------------

class Counter:
    """Monotone counter with run + lifetime scopes."""

    __slots__ = ("name", "help", "_run", "_life")

    def __init__(self, name: str, help: str = ""):  # noqa: A002 - prom idiom
        self.name = name
        self.help = help
        self._run = 0
        self._life = 0

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        self._run += n
        self._life += n

    @property
    def value(self):
        return self._run

    @property
    def lifetime(self):
        return self._life

    def set_run(self, value) -> None:
        """Set the run-scope value directly (the stats-facade write path:
        ``stats[k] += n`` reads then assigns).  The lifetime scope absorbs
        the delta, staying monotone across resets."""
        delta = value - self._run
        if delta < 0:
            raise ValueError(
                f"counter {self.name}: run value may not decrease "
                f"({self._run} -> {value}); use reset_run() to zero it"
            )
        self._run = value
        self._life += delta

    def reset_run(self) -> None:
        self._run = 0


class Gauge:
    """Last-set value.  Run scope only (a gauge has no meaningful sum);
    ``reset_run`` returns it to 0."""

    __slots__ = ("name", "help", "_value")

    def __init__(self, name: str, help: str = ""):  # noqa: A002
        self.name = name
        self.help = help
        self._value = 0.0

    def set(self, value) -> None:
        self._value = value

    def set_max(self, value) -> None:
        """Peak tracking: keep the maximum of all sets since the last reset."""
        if value > self._value:
            self._value = value

    @property
    def value(self):
        return self._value

    def reset_run(self) -> None:
        self._value = 0.0


class Histogram:
    """Full-sample histogram; percentiles are exact (nearest-rank).

    Run samples are zeroed by ``reset_run``; the lifetime sample list keeps
    accumulating (bounded by tokens served per engine — fine at serving
    scale, and it keeps lifetime percentiles exact too).
    """

    __slots__ = ("name", "help", "_run", "_life")

    def __init__(self, name: str, help: str = ""):  # noqa: A002
        self.name = name
        self.help = help
        self._run: List[float] = []
        self._life: List[float] = []

    def observe(self, value: float) -> None:
        self._run.append(value)
        self._life.append(value)

    @property
    def count(self) -> int:
        return len(self._run)

    @property
    def values(self) -> Tuple[float, ...]:
        return tuple(self._run)

    def percentile(self, p: float, scope: str = "run") -> float:
        return percentile(self._samples(scope), p)

    def value_counts(self, scope: str = "run") -> Dict[float, int]:
        """``{observed value: occurrences}`` — the discrete view backing
        ``stats["spec_accept_counts"]``."""
        out: Dict[float, int] = {}
        for v in self._samples(scope):
            out[v] = out.get(v, 0) + 1
        return out

    def summary(self, scope: str = "run",
                ps: Iterable[float] = (50, 95, 99)) -> Dict[str, float]:
        return percentile_summary(self._samples(scope), ps)

    def _samples(self, scope: str) -> List[float]:
        if scope == "run":
            return self._run
        if scope == "lifetime":
            return self._life
        raise ValueError(f"unknown scope {scope!r}")

    def reset_run(self) -> None:
        self._run = []


# --- registry -----------------------------------------------------------------

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    """Sanitize to the Prometheus metric-name charset."""
    name = _NAME_RE.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


class MetricsRegistry:
    """Get-or-create instrument registry with snapshot/Prometheus export."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # --- get-or-create ------------------------------------------------------
    def _get(self, table: Dict, cls, name: str, help: str):  # noqa: A002
        inst = table.get(name)
        if inst is None:
            for other in (self._counters, self._gauges, self._histograms):
                if other is not table and name in other:
                    raise ValueError(
                        f"metric {name!r} already registered as a different kind"
                    )
            inst = table[name] = cls(name, help)
        return inst

    def counter(self, name: str, help: str = "") -> Counter:  # noqa: A002
        return self._get(self._counters, Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:  # noqa: A002
        return self._get(self._gauges, Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:  # noqa: A002
        return self._get(self._histograms, Histogram, name, help)

    def __contains__(self, name: str) -> bool:
        return (name in self._counters or name in self._gauges
                or name in self._histograms)

    def names(self) -> List[str]:
        return (sorted(self._counters) + sorted(self._gauges)
                + sorted(self._histograms))

    # --- scopes -------------------------------------------------------------
    def reset_run(self) -> None:
        """Zero the run scope of every instrument; lifetime scopes survive."""
        for table in (self._counters, self._gauges, self._histograms):
            for inst in table.values():
                inst.reset_run()

    # --- export -------------------------------------------------------------
    def snapshot(self, scope: str = "run") -> Dict[str, Dict]:
        """Plain nested dict of every instrument's current state.

        ``scope="run"`` is the window since the last ``reset_run``;
        ``scope="lifetime"`` is since registry construction.  Gauges carry
        no lifetime scope and always report their current value.
        """
        if scope not in ("run", "lifetime"):
            raise ValueError(f"unknown scope {scope!r}")
        counters = {
            n: (c.value if scope == "run" else c.lifetime)
            for n, c in sorted(self._counters.items())
        }
        gauges = {n: g.value for n, g in sorted(self._gauges.items())}
        hists = {n: h.summary(scope) for n, h in sorted(self._histograms.items())}
        return {"scope": scope, "counters": counters, "gauges": gauges,
                "histograms": hists}

    def to_prometheus(self, scope: str = "run",
                      prefix: str = "repro_") -> str:
        """Prometheus text exposition: counters as ``<name>_total``, gauges
        bare, histograms as summaries (``quantile`` labels + _sum/_count)."""
        lines: List[str] = []
        for n, c in sorted(self._counters.items()):
            pn = _prom_name(prefix + n)
            if c.help:
                lines.append(f"# HELP {pn}_total {c.help}")
            lines.append(f"# TYPE {pn}_total counter")
            v = c.value if scope == "run" else c.lifetime
            lines.append(f"{pn}_total {v}")
        for n, g in sorted(self._gauges.items()):
            pn = _prom_name(prefix + n)
            if g.help:
                lines.append(f"# HELP {pn} {g.help}")
            lines.append(f"# TYPE {pn} gauge")
            lines.append(f"{pn} {g.value}")
        for n, h in sorted(self._histograms.items()):
            pn = _prom_name(prefix + n)
            if h.help:
                lines.append(f"# HELP {pn} {h.help}")
            lines.append(f"# TYPE {pn} summary")
            s = h.summary(scope)
            for q in (0.5, 0.95, 0.99):
                v = s[f"p{q * 100:g}"]
                if v == v:  # skip NaN quantiles of empty histograms
                    lines.append(f'{pn}{{quantile="{q}"}} {v}')
            lines.append(f"{pn}_sum {s['sum']}")
            lines.append(f"{pn}_count {s['n']}")
        return "\n".join(lines) + "\n"
