"""Sim-vs-measured drift monitor: is the costmodel still telling the truth?

``experiments/calibrate.py`` proved the analytic costmodel drifts from real
hardware and fitted it back once, offline.  This module makes that signal
permanent: every executed serving step is *priced* with the same simulator
machinery the planner uses (``core/simulator.make_step_pricer`` over
``simulate_execplan`` — decode as the 1-row suffix case, prefill chunks and
speculative verify chunks as k-row suffix prefills) and the
``measured / simulated`` ratio lands in a histogram per step kind.

A ratio of 1.0 means the costmodel prices this cluster perfectly; a drifting
p50 means the plan the engine is executing was solved against stale numbers
— exactly the trigger the ROADMAP's elastic-serving replanner needs
(re-solve the ExecPlan when drift crosses a threshold, instead of on a
timer).

The monitor is opt-in and engine-driven: the engine stamps
``time.perf_counter`` around steps that already end on a host sync point
(decode steps and speculative verify chunks sync when their logits are
sampled; mid-prompt prefill chunks are dispatch-only and are priced with
``synced=False`` so their ratios land in a separate ``*_dispatch``
histogram rather than polluting the wall-time ones).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.obs.metrics import MetricsRegistry, percentile_summary

__all__ = ["DriftMonitor"]

# pricer(kind, rows=, context=) -> simulated seconds (None = unpriceable)
StepPricer = Callable[..., Optional[float]]


class DriftMonitor:
    """Record measured/simulated ratios of executed serving steps.

    pricer:   ``core/simulator.make_step_pricer(...)`` or any callable with
              the same shape — ``pricer(kind, rows=, context=)`` returning
              modeled seconds for one step (``None`` skips the observation).
    registry: the engine's :class:`MetricsRegistry`; the engine binds its
              own when the monitor is handed over unbound, so the drift
              histograms show up in ``engine.metrics.snapshot()``.
    """

    def __init__(self, pricer: StepPricer,
                 registry: Optional[MetricsRegistry] = None):
        self.pricer = pricer
        self.registry = registry
        self.records: List[Dict] = []

    def observe(self, kind: str, measured_s: float, *, rows: int = 1,
                context: int = 0, synced: bool = True) -> Optional[float]:
        """Price one executed step and record measured/simulated.

        ``synced=False`` marks steps whose measured time is host dispatch
        only (no sync point before the stamp): they are still recorded, in
        a ``*_dispatch`` histogram, because dispatch-time drift is a real
        (if weaker) signal — but the headline ``sim_drift_ratio`` histogram
        stays wall-time-only.
        """
        sim = self.pricer(kind, rows=rows, context=context)
        if sim is None or sim <= 0 or measured_s < 0:
            return None
        ratio = measured_s / sim
        self.records.append({
            "kind": kind, "rows": rows, "context": context,
            "measured_s": measured_s, "simulated_s": sim, "ratio": ratio,
            "synced": synced,
        })
        if self.registry is not None:
            suffix = "" if synced else "_dispatch"
            self.registry.histogram(
                f"sim_drift_ratio{suffix}",
                "measured / simulated step latency",
            ).observe(ratio)
            self.registry.histogram(
                f"sim_drift_ratio_{kind}{suffix}",
                f"measured / simulated {kind} latency",
            ).observe(ratio)
        return ratio

    def summary(self) -> Dict[str, Dict]:
        """Per-kind ratio percentiles over everything observed so far."""
        by_kind: Dict[str, List[float]] = {}
        for r in self.records:
            key = r["kind"] + ("" if r["synced"] else "_dispatch")
            by_kind.setdefault(key, []).append(r["ratio"])
            by_kind.setdefault("all" if r["synced"] else "all_dispatch",
                               []).append(r["ratio"])
        return {k: percentile_summary(v) for k, v in sorted(by_kind.items())}
