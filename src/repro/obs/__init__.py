"""Serving observability: span tracing, a metrics registry, and the
sim-vs-measured drift monitor.

* ``obs.trace`` — :class:`Tracer` records structured spans for every
  request lifecycle event and engine loop step, exported as Chrome
  trace-event JSON (open a serve run in ``chrome://tracing`` or
  https://ui.perfetto.dev).
* ``obs.metrics`` — :class:`MetricsRegistry` of counters / gauges /
  histograms with run-vs-lifetime scopes, ``snapshot()`` and Prometheus
  text export; also the one shared percentile/TTFT/ITL helper family the
  benchmarks read.
* ``obs.drift`` — :class:`DriftMonitor` prices each executed serving step
  with the planner's own simulator and histograms the measured/simulated
  ratio, turning the one-off ``experiments/calibrate.py`` loop into a live
  costmodel-drift signal.

Everything here is opt-in on the serving hot path: an engine without a
tracer/drift monitor executes zero telemetry instructions per token.
"""
from repro.obs.drift import DriftMonitor
from repro.obs.metrics import (
    Counter, Gauge, Histogram, MetricsRegistry,
    itl_seconds, percentile, percentile_summary,
    ttft_percentiles, ttft_seconds,
)
from repro.obs.trace import RequestTracks, Tracer

__all__ = [
    "Tracer", "RequestTracks",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "percentile", "percentile_summary",
    "ttft_seconds", "itl_seconds", "ttft_percentiles",
    "DriftMonitor",
]
