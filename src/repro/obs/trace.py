"""Span tracing: where did this request's milliseconds go?

A :class:`Tracer` records structured spans for every serving lifecycle
event and exports them as Chrome trace-event JSON (the ``traceEvents``
array format), so a serve run opens directly in ``chrome://tracing`` or
https://ui.perfetto.dev.

Model
-----
* One *process* (``pid``) per tracer; one *thread track* (``tid``) per
  label — the engine uses the ``"engine"`` track for its loop steps
  (admit / prefill_chunk / decode_step / spec_round) and one ``"req <uid>"``
  track per request for its lifecycle phases (queued → prefill → decode),
  which tile the request's submit→retire wall time contiguously.
* Spans follow strict stack discipline per track: :meth:`Tracer.begin`
  pushes, :meth:`Tracer.end` pops and emits one *complete* event
  (``ph="X"`` with ``ts``/``dur`` in microseconds).  Stack discipline makes
  un-nested or out-of-order spans unrepresentable, and durations are
  clamped at >= 0 against clock quirks.
* :meth:`Tracer.instant` marks zero-duration events (submit, rollback).

Overhead discipline: tracing never synchronizes the device (no
``block_until_ready``); span boundaries land on the host-side dispatch
points the engine already passes through, and the engine only *calls* the
tracer when one was passed and is enabled — a run without a tracer
executes zero tracing instructions per token (gated structurally in
``tests/test_obs.py``).  Host-side timestamps mean an engine-track span
that ends before the next sync point measures dispatch, not device time;
the request-phase spans end on real sync points (a sampled token, a
retirement) and are what the >=95 %-coverage acceptance gate reads.

:class:`RequestTracks` is the small per-request phase bookkeeper the
engine drives (and the hypothesis property test in ``tests/test_obs.py``
hammers with random admit/retire/spec interleavings): phases are strictly
sequential per request, every transition closes the previous phase, and
``finish`` closes whatever is open — so a tracer owned by an engine ends
every run with zero open spans.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple, Union

__all__ = ["Tracer", "RequestTracks"]


class Tracer:
    """Structured span recorder with Chrome trace-event export."""

    def __init__(self, *, enabled: bool = True, pid: int = 1,
                 process_name: str = "repro-serving", clock=None):
        self.enabled = enabled
        self.pid = pid
        self._clock = clock or time.perf_counter
        self._t0 = self._clock()
        self._events: List[dict] = []
        self._tids: Dict[str, int] = {}
        # per-tid stack of open spans: (name, cat, ts_us, args)
        self._open: Dict[int, List[Tuple[str, str, float, dict]]] = {}
        self._meta: List[dict] = [{
            "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "args": {"name": process_name},
        }]

    # --- clock / tracks -----------------------------------------------------
    def _now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def tid(self, track: Union[str, int]) -> int:
        """Stable integer track id for a label (creates the track and its
        ``thread_name`` metadata on first use)."""
        if isinstance(track, int):
            return track
        t = self._tids.get(track)
        if t is None:
            t = self._tids[track] = len(self._tids) + 1
            self._meta.append({
                "ph": "M", "name": "thread_name", "pid": self.pid, "tid": t,
                "args": {"name": track},
            })
        return t

    # --- spans --------------------------------------------------------------
    def begin(self, track: Union[str, int], name: str, cat: str = "serve",
              **args) -> None:
        tid = self.tid(track)
        self._open.setdefault(tid, []).append(
            (name, cat, self._now_us(), dict(args)))

    def end(self, track: Union[str, int], **extra_args) -> None:
        tid = self.tid(track)
        stack = self._open.get(tid)
        if not stack:
            raise RuntimeError(f"end() on track {track!r} with no open span")
        name, cat, ts, args = stack.pop()
        if extra_args:
            args.update(extra_args)
        self._events.append({
            "name": name, "cat": cat, "ph": "X", "pid": self.pid, "tid": tid,
            "ts": ts, "dur": max(0.0, self._now_us() - ts), "args": args,
        })

    @contextmanager
    def span(self, track: Union[str, int], name: str, cat: str = "serve",
             **args):
        self.begin(track, name, cat, **args)
        try:
            yield self
        finally:
            self.end(track)

    def instant(self, track: Union[str, int], name: str, cat: str = "serve",
                **args) -> None:
        self._events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t", "pid": self.pid,
            "tid": self.tid(track), "ts": self._now_us(), "args": dict(args),
        })

    # --- export -------------------------------------------------------------
    def open_spans(self) -> List[Tuple[int, str]]:
        """(tid, name) of every span begun but not yet ended."""
        return [(tid, frame[0])
                for tid, stack in self._open.items() for frame in stack]

    @property
    def events(self) -> List[dict]:
        return list(self._events)

    def to_json(self, *, allow_open: bool = False) -> dict:
        """The ``chrome://tracing`` / Perfetto JSON object.

        Raises if spans are still open (an engine bug — every lifecycle
        path must close its spans) unless ``allow_open=True``.
        """
        if not allow_open and self.open_spans():
            raise RuntimeError(
                f"trace export with open spans: {self.open_spans()}"
            )
        return {
            "traceEvents": self._meta + sorted(
                self._events, key=lambda e: e["ts"]),
            "displayTimeUnit": "ms",
        }

    def write(self, path: str, *, allow_open: bool = False) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(allow_open=allow_open), f)


class RequestTracks:
    """Per-request lifecycle phases over a :class:`Tracer`.

    Drives one track per request uid through the strictly sequential phase
    chain ``queued -> prefill -> decode -> (closed)``; every transition
    closes the previous phase at the same timestamp it opens the next, so
    the phases tile submit→retire wall time with no gaps (the >=95 %
    span-coverage acceptance gate) and no request ever retires with an
    open span.
    """

    PHASES = ("queued", "prefill", "decode")

    def __init__(self, tracer: Tracer):
        self.tracer = tracer
        self._phase: Dict[int, Optional[str]] = {}

    def _track(self, uid: int) -> str:
        return f"req {uid}"

    def submit(self, uid: int) -> None:
        if uid in self._phase:
            raise ValueError(f"request {uid} already tracked")
        self.tracer.instant(self._track(uid), "submit")
        self.tracer.begin(self._track(uid), "queued", uid=uid)
        self._phase[uid] = "queued"

    def phase(self, uid: int, name: str, **args) -> None:
        """Advance to ``name``, closing the currently open phase.  Phases
        may be skipped but never revisited (monotone along ``PHASES``)."""
        cur = self._phase.get(uid)
        if cur is None:
            raise ValueError(f"request {uid} is not in an open phase")
        if self.PHASES.index(name) <= self.PHASES.index(cur):
            raise ValueError(
                f"request {uid}: phase {name!r} after {cur!r} is not monotone"
            )
        self.tracer.end(self._track(uid))
        self.tracer.begin(self._track(uid), name, uid=uid, **args)
        self._phase[uid] = name

    def event(self, uid: int, name: str, **args) -> None:
        """Zero-duration marker on the request's track (rollback, eviction)."""
        if self._phase.get(uid) is None:
            raise ValueError(f"request {uid} is not in an open phase")
        self.tracer.instant(self._track(uid), name, **args)

    def finish(self, uid: int, **args) -> None:
        """Close the open phase (retirement — from any phase)."""
        if self._phase.get(uid) is None:
            raise ValueError(f"request {uid} is not in an open phase")
        self.tracer.end(self._track(uid), **args)
        self._phase[uid] = None

    def is_open(self, uid: int) -> bool:
        return self._phase.get(uid) is not None

    def open_uids(self) -> List[int]:
        return [uid for uid, ph in self._phase.items() if ph is not None]
