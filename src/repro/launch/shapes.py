"""Assigned input shapes and their ShapeDtypeStruct stand-ins.

  train_4k       seq_len=  4,096  global_batch= 256  (training)
  prefill_32k    seq_len= 32,768  global_batch=  32  (inference-prefill)
  decode_32k     seq_len= 32,768  global_batch= 128  (inference-decode)
  long_500k      seq_len=524,288  global_batch=   1  (long-context-decode)

Decode shapes lower ``serve_step`` (ONE token against a seq_len cache).
long_500k substitutes the sliding-window attention variant for otherwise-
quadratic archs (DESIGN.md §4) — the cache is then window-sized.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding import Rules
from repro.serving.kvcache import make_cache

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, mode="train"),
    "prefill_32k": dict(seq=32768, batch=32, mode="prefill"),
    "decode_32k": dict(seq=32768, batch=128, mode="decode"),
    "long_500k": dict(seq=524288, batch=1, mode="decode_long"),
}


def shape_config(cfg: ModelConfig, shape: str) -> ModelConfig:
    """Per-shape config variant: long_500k swaps in sliding-window attention
    for archs whose native attention is quadratic."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return dataclasses.replace(cfg, window=cfg.long_context_window)
    return cfg


def _sds(shape, dtype, rules: Optional[Rules], axes):
    sharding = None
    if rules is not None and rules.mesh is not None:
        sharding = jax.sharding.NamedSharding(rules.mesh, rules.spec(axes, shape=shape))
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype), sharding=sharding)


def input_specs(cfg: ModelConfig, shape: str, rules: Optional[Rules] = None) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape —
    weak-type-correct, shardable, no device allocation."""
    info = SHAPES[shape]
    seq, batch, mode = info["seq"], info["batch"], info["mode"]
    cfg = shape_config(cfg, shape)
    specs: Dict = {}

    if mode == "train":
        if cfg.input_mode == "token":
            specs["tokens"] = _sds((batch, seq), "int32", rules, ("batch", "seq"))
            specs["labels"] = _sds((batch, seq), "int32", rules, ("batch", "seq"))
        else:
            specs["embeds"] = _sds((batch, seq, cfg.d_model), cfg.dtype, rules,
                                   ("batch", "seq", "embed"))
            lab_axes = ("batch", "seq") if cfg.num_codebooks <= 1 else ("batch", "seq", None)
            lab_shape = (batch, seq) if cfg.num_codebooks <= 1 else (batch, seq, cfg.num_codebooks)
            specs["labels"] = _sds(lab_shape, "int32", rules, lab_axes)
    elif mode == "prefill":
        if cfg.input_mode == "token":
            specs["tokens"] = _sds((batch, seq), "int32", rules, ("batch", "seq"))
        else:
            specs["embeds"] = _sds((batch, seq, cfg.d_model), cfg.dtype, rules,
                                   ("batch", "seq", "embed"))
    else:  # decode / decode_long
        if cfg.input_mode == "token":
            specs["tokens"] = _sds((batch, 1), "int32", rules, ("batch", None))
        else:
            specs["embeds"] = _sds((batch, 1, cfg.d_model), cfg.dtype, rules,
                                   ("batch", None, "embed"))
        specs["cache"] = make_cache(cfg, batch, seq, abstract=True, rules=rules)
        specs["cache_index"] = jax.ShapeDtypeStruct((), jnp.int32)

    if cfg.num_image_tokens:
        specs["img_embeds"] = _sds(
            (batch, cfg.num_image_tokens, cfg.d_model), cfg.dtype, rules,
            ("batch", "img_seq", "embed"),
        )
    return specs
