"""End-to-end training driver.

Runs real training on this host (CPU: use a reduced config) or, with
--mesh, the sharded production layout.  Example (the (b) deliverable's
"train a ~100M model for a few hundred steps" — see examples/train_small.py
for the canonical invocation):

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --reduce --steps 300 --batch 8 --seq 128 --ckpt /tmp/run1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.data import DataConfig, LMDataPipeline
from repro.models import init_params
from repro.training import AdamW, cosine_schedule, make_train_step, save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ASSIGNED_ARCHS)
    ap.add_argument("--reduce", action="store_true",
                    help="train the reduced (smoke-size) variant")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--text", default=None, help="optional text corpus path")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg, d_model=args.d_model)
    print(f"training {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"params={cfg.param_count()/1e6:.1f}M")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(cosine_schedule(args.lr, args.warmup, args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))

    pipe = iter(LMDataPipeline(cfg, DataConfig(
        batch_size=args.batch, seq_len=args.seq, text_path=args.text)))

    t0 = time.time()
    tokens_seen = 0
    for step in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jax.random.PRNGKey(step))
        tokens_seen += args.batch * args.seq
        if step % args.log_every == 0 or step == 1:
            jax.block_until_ready(metrics["loss"])
            rate = tokens_seen / (time.time() - t0)
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} tok/s={rate:,.0f}")
        if args.ckpt and step % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, step, params, opt_state,
                            {"arch": cfg.name})
            print(f"  checkpoint @ {step} -> {args.ckpt}")
    print(f"done: {args.steps} steps, {tokens_seen:,} tokens, "
          f"{time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
