import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: .lower().compile() every (arch x input-shape x mesh).

For each combination this builds the sharded step function (train_step /
prefill_step / serve_step) from abstract inputs (ShapeDtypeStruct — no
allocation), lowers and compiles it against the production mesh, and
records memory_analysis + cost_analysis + the collective schedule for the
roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--hmp-mode tp_only]
Results append to experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import ModelConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, input_specs, shape_config
from repro.models.params import abstract_params
from repro.models.sharding import Rules, axis_rules, make_rules
from repro.models.transformer import apply_model
from repro.roofline.analysis import Roofline, collective_bytes, model_flops
from repro.training.optimizer import AdamW, cosine_schedule

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _mode_of(shape: str) -> str:
    return SHAPES[shape]["mode"]


def build_step(cfg: ModelConfig, shape: str, rules: Rules, unroll: bool = False):
    """Returns (fn, abstract_args) for the step this shape exercises."""
    mode = _mode_of(shape)
    specs = input_specs(cfg, shape, rules)
    aparams = abstract_params(cfg, rules)

    if mode == "train":
        opt = AdamW(cosine_schedule(3e-4, 100, 10000))
        mu = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=p.sharding),
            aparams,
        )
        astate = (jax.ShapeDtypeStruct((), jnp.int32), mu, mu)
        from repro.training.train_loop import loss_fn

        def train_step(params, opt_state, batch):
            from repro.training.optimizer import AdamWState

            with axis_rules(rules):
                (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, batch, cfg, None, unroll
                )
                params, new_state, _ = opt.update(
                    grads, AdamWState(*opt_state), params
                )
            return params, tuple(new_state), loss

        return train_step, (aparams, astate, specs)

    if mode == "prefill":
        def prefill_step(params, batch):
            with axis_rules(rules):
                logits, cache, _ = apply_model(
                    params, cfg, mode="prefill", cache=None, unroll=unroll, **batch
                )
            return logits[:, -1], cache

        return prefill_step, (aparams, specs)

    # decode / decode_long -> serve_step: ONE new token against the cache
    def serve_step(params, batch):
        cache = batch["cache"]
        index = batch["cache_index"]
        kwargs = {k: v for k, v in batch.items() if k not in ("cache", "cache_index")}
        with axis_rules(rules):
            logits, new_cache, _ = apply_model(
                params, cfg, mode="decode", cache=cache, cache_index=index,
                unroll=unroll, **kwargs
            )
        return logits[:, -1], new_cache

    return serve_step, (aparams, specs)


def _xlstm_scan_correction(cfg: ModelConfig, shape: str, chips: int) -> float:
    """Analytic per-chip FLOPs for m/sLSTM *time-scan* inner recurrences,
    which sit in while loops XLA's cost_analysis counts once.  The q/k/v and
    up/down projections run outside the time scan and are counted normally.
    Training roughly triples the recurrence work (fwd + bwd)."""
    kinds = cfg.layer_kinds()
    n_m = sum(1 for k in kinds if k == "mlstm")
    n_s = sum(1 for k in kinds if k == "slstm")
    if n_m + n_s == 0:
        return 0.0
    info = SHAPES[shape]
    tokens = info["batch"] * (info["seq"] if info["mode"] in ("train", "prefill") else 1)
    di = int(cfg.d_model * cfg.proj_factor)
    nh = cfg.num_heads
    dh = di // nh
    per_tok_m = 8.0 * nh * dh * dh      # C update + C·q + n ops
    per_tok_s = 8.0 * nh * dh * dh + 40.0 * nh * dh  # recurrent matmul + gates
    total = tokens * (n_m * per_tok_m + n_s * per_tok_s)
    if info["mode"] == "train":
        total *= 3.0
    return total / chips


def _lower_compile(cfg, shape, rules, mesh, unroll: bool = False):
    fn, args = build_step(cfg, shape, rules, unroll=unroll)
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def _cost_tuple(compiled):
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        coll,
    )


def run_one(arch: str, shape: str, *, multi_pod: bool = False,
            hmp_sequence_parallel: bool = True, save: bool = True,
            verbose: bool = True, variant: str = "",
            cfg_overrides: Optional[dict] = None,
            rules_overrides: Optional[dict] = None) -> dict:
    """``variant`` tags the output file; ``cfg_overrides`` are
    dataclasses.replace fields (e.g. attn_chunk=1024, param_dtype=...);
    ``rules_overrides`` are extra make_rules kwargs (§Perf hillclimbs)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size

    base_cfg = get_config(arch)
    cfg = shape_config(base_cfg, shape)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    info = SHAPES[shape]
    rules = make_rules(
        mesh, info["mode"], multi_pod=multi_pod, batch_size=info["batch"],
        hmp_sequence_parallel=hmp_sequence_parallel,
        **(rules_overrides or {}),
    )

    # --- full-depth compile: THE multi-pod proof + memory analysis ---------
    t0 = time.time()
    fn, args = build_step(cfg, shape, rules)
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()

    # --- roofline terms: XLA's cost_analysis counts a scanned layer-group
    # body ONCE, not x trip-count.  Measure per-group costs from UNROLLED
    # G=1 and G=2 compiles: total = base(G=1) + delta_per_group*(groups-1).
    plen = len(cfg.block_pattern)
    tail = len(cfg.tail_pattern)
    g_full = cfg.num_groups
    cfg1 = dataclasses.replace(cfg, num_layers=1 * plen + tail)
    cfg2 = dataclasses.replace(cfg, num_layers=2 * plen + tail)
    _, c1 = _lower_compile(cfg1, shape, rules, mesh, unroll=True)
    f1, b1, coll1 = _cost_tuple(c1)
    _, c2 = _lower_compile(cfg2, shape, rules, mesh, unroll=True)
    f2, b2, coll2 = _cost_tuple(c2)
    n_extra = g_full - 1
    hlo_flops = f1 + (f2 - f1) * n_extra
    hlo_bytes = b1 + (b2 - b1) * n_extra
    coll = {
        k: coll1.get(k, 0.0) + (coll2.get(k, 0.0) - coll1.get(k, 0.0)) * n_extra
        for k in set(coll1) | set(coll2)
    }
    # inner *time* scans (m/sLSTM) still sit in while loops: analytic add-in
    hlo_flops += _xlstm_scan_correction(cfg, shape, chips)

    mf = model_flops(cfg, info, training=info["mode"] == "train")
    rl = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=hlo_flops,
        hlo_bytes=hlo_bytes,
        coll_bytes=coll,
        model_flops=mf,
        peak_mem_bytes=getattr(mem, "temp_size_in_bytes", None),
        dtype_factor=0.5 if cfg.dtype == "bfloat16" else 1.0,
    )
    record = rl.to_dict()
    record.update(
        hmp_sequence_parallel=hmp_sequence_parallel,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        argument_bytes=getattr(mem, "argument_size_in_bytes", None),
        output_bytes=getattr(mem, "output_size_in_bytes", None),
        temp_bytes=getattr(mem, "temp_size_in_bytes", None),
        generated_code_bytes=getattr(mem, "generated_code_size_in_bytes", None),
    )
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        suffix = "" if hmp_sequence_parallel else "__tp_only"
        if variant:
            suffix += f"__{variant}"
        path = os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=2)
    if verbose:
        print(
            f"[dryrun] {arch:24s} {shape:12s} mesh={mesh_name:9s} OK "
            f"flops/chip={record['hlo_flops_per_chip']:.3e} "
            f"coll/chip={coll.get('total', 0)/1e6:.1f}MB "
            f"bottleneck={record['bottleneck']} "
            f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
            flush=True,
        )
        if mem is not None:
            print(f"  memory_analysis: {mem}", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ASSIGNED_ARCHS) + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tp-only", action="store_true",
                    help="disable HMP sequence parallelism (Megatron-TP baseline)")
    ap.add_argument("--continue-on-error", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip combos whose result JSON already exists")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    # smallest archs first: early results bank fast, big compiles last
    archs.sort(key=lambda a: get_config(a).param_count())

    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    failures = []
    for arch in archs:
        for shape in shapes:
            suffix = "__tp_only" if args.tp_only else ""
            path = os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_name}{suffix}.json")
            if args.resume and os.path.exists(path):
                print(f"[dryrun] {arch} {shape} {mesh_name} cached, skipping", flush=True)
                continue
            try:
                run_one(arch, shape, multi_pod=args.multi_pod,
                        hmp_sequence_parallel=not args.tp_only)
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, repr(e)))
                print(f"[dryrun] {arch} {shape} FAILED: {e}", flush=True)
                if not args.continue_on_error:
                    traceback.print_exc()
                    raise
    if failures:
        print(f"{len(failures)} failures:", *failures, sep="\n  ")
        raise SystemExit(1)
    print("all dry-runs passed")


if __name__ == "__main__":
    main()
