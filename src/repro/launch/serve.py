"""End-to-end serving driver: batched requests through the serving engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduce \
      --requests 16 --prompt-len 32 --max-new 32

``--executor galaxy`` serves through the paper-exact Galaxy HMP schedule on
all local devices (an even ExecPlan over the device mesh) instead of the
GSPMD model zoo; there ``--compute-backend pallas`` switches the per-shard
compute path to the valid-length Pallas kernels (``ExecPlan.compute_backend``
— pad-block work is shed per device; "xla" keeps the padded dense oracle).

``--prefix-cache on`` shares prompt-prefix KV across requests through the
radix-tree cache (``serving/prefix_cache.py``; requests get a common system
prompt so hits occur) and ``--prefill-chunk N`` interleaves N-token prefill
chunks with decode steps — both continuous-scheduler features, on either
executor.

``--draft-model <zoo-arch> --spec-k N`` turns on speculative decoding
(``serving/spec.py``): the draft arch proposes N tokens per round on the
fastest device and the serving executor verifies them in one chunked paged
prefill — greedy-only, continuous scheduler only, output bitwise-identical
to plain decoding.

Telemetry (``repro.obs``): ``--trace out.json`` records request/engine
spans and writes Chrome trace-event JSON (open in ``chrome://tracing`` or
https://ui.perfetto.dev), ``--metrics`` prints the metrics-registry
snapshot plus its Prometheus text rendering, and ``--drift`` prices every
executed step with the planner's simulator and reports measured/simulated
drift ratios.  All three are opt-in; none changes the emitted tokens.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import init_params
from repro.serving import (
    Request, SamplerConfig, ServingEngine, TransformerExecutor,
)


def _galaxy_executor(cfg, compute_backend: str):
    """An even Galaxy HMP executor over every local device."""
    from repro.core import hmp
    from repro.core.execplan import ExecPlan
    from repro.launch.mesh import make_mesh_compat
    from repro.serving import GalaxyHMPExecutor

    n = jax.device_count()
    if cfg.num_heads % n or cfg.d_ff % n:
        raise SystemExit(
            f"{cfg.name}: {cfg.num_heads} heads / {cfg.d_ff} columns do not "
            f"split over {n} local devices — pick a dividing arch/--reduce"
        )
    plan = ExecPlan.even(n, num_heads=cfg.num_heads, d_ff=cfg.d_ff,
                         head_dim=cfg.head_dim, d_model=cfg.d_model)
    mesh = make_mesh_compat((n,), ("model",))
    layers = hmp.init_stack_params(
        jax.random.PRNGKey(0), cfg.num_layers, cfg.d_model, cfg.num_heads,
        cfg.d_ff)
    embed = jax.random.normal(
        jax.random.PRNGKey(1), (cfg.vocab_size, cfg.d_model)) * 0.02
    return GalaxyHMPExecutor(layers, embed, plan, mesh,
                             compute_backend=compute_backend)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ASSIGNED_ARCHS)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--scheduler", choices=("auto", "continuous", "wave"),
                    default="auto",
                    help="auto = continuous batching when the executor "
                         "implements the paged protocol, else waves")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV pool page size (continuous batching)")
    ap.add_argument("--prefix-cache", choices=("on", "off"), default="off",
                    help="shared-prefix KV cache (serving/prefix_cache.py): "
                         "requests with a common page-aligned prompt prefix "
                         "map it to the same refcounted pool pages and "
                         "prefill only the uncached suffix (continuous "
                         "scheduler only)")
    ap.add_argument("--prefill-chunk", type=int, default=None, metavar="N",
                    help="chunked prefill: interleave N-token prefill chunks "
                         "with decode steps instead of stalling live slots "
                         "for a whole long-prompt prefill (continuous "
                         "scheduler only)")
    ap.add_argument("--draft-model", default=None, metavar="ARCH",
                    help="speculative decoding (serving/spec.py): a small "
                         "zoo arch drafts --spec-k tokens per round on the "
                         "fastest device and the serving executor verifies "
                         "them in one chunked paged prefill (greedy only, "
                         "continuous scheduler only)")
    ap.add_argument("--spec-k", type=int, default=None, metavar="N",
                    help="draft tokens proposed per speculative round "
                         "(requires --draft-model)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record request/engine spans and write Chrome "
                         "trace-event JSON (chrome://tracing, "
                         "ui.perfetto.dev)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the metrics registry snapshot (TTFT/ITL "
                         "percentiles, pool occupancy, hit/acceptance "
                         "rates) and its Prometheus text rendering")
    ap.add_argument("--drift", action="store_true",
                    help="price every executed step with the planner's "
                         "simulator (core/simulator.make_step_pricer) and "
                         "report measured/simulated drift ratios "
                         "(diagnostics: syncs once per prefill chunk)")
    ap.add_argument("--executor", choices=("zoo", "galaxy"), default="zoo",
                    help="zoo = GSPMD model zoo; galaxy = paper-exact HMP "
                         "schedule over all local devices")
    ap.add_argument("--compute-backend", choices=("xla", "pallas"),
                    default="xla",
                    help="Galaxy per-shard compute path "
                         "(ExecPlan.compute_backend): 'pallas' sheds "
                         "pad-block work via the valid-length kernels; "
                         "'xla' is the padded dense oracle.  Galaxy "
                         "executor only — the zoo path is GSPMD-sharded "
                         "and has no padded shards to shed")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    if cfg.input_mode != "token":
        raise SystemExit(f"{cfg.name} is a stub-frontend arch; serve the token archs")

    draft_executor = None
    if (args.draft_model is None) != (args.spec_k is None):
        raise SystemExit("--draft-model and --spec-k go together")
    if args.draft_model is not None:
        if args.scheduler == "wave":
            raise SystemExit(
                "--draft-model requires the continuous scheduler: the wave "
                "path has no paged chunk-prefill to verify drafts with "
                "(drop --scheduler wave)")
        if args.temperature != 0.0:
            raise SystemExit(
                "--draft-model is greedy-only: verification pins tokens to "
                "the sequential argmax path (drop --temperature)")
        from repro.core.costmodel import DeviceSpec
        from repro.serving import place_draft

        draft_cfg = get_config(args.draft_model)
        if args.reduce:
            draft_cfg = reduced(draft_cfg)
        if draft_cfg.vocab_size != cfg.vocab_size:
            raise SystemExit(
                f"draft {draft_cfg.name} vocab {draft_cfg.vocab_size} != "
                f"target vocab {cfg.vocab_size}")
        draft_params = init_params(draft_cfg, jax.random.PRNGKey(2))
        # the draft runs alone on one device; place_draft picks the
        # highest-FLOPS spec (local devices report uniform capacity, so
        # this degenerates to index 0 — on a real heterogeneous edge mesh
        # the DeviceSpecs come from the profiler)
        specs = [DeviceSpec(str(d), 1.0, 1.0, 1.0) for d in jax.local_devices()]
        dev = jax.local_devices()[place_draft(specs)]
        draft_params = jax.device_put(draft_params, dev)
        draft_executor = TransformerExecutor(draft_params, draft_cfg)

    if args.executor == "galaxy":
        executor = _galaxy_executor(cfg, args.compute_backend)
    else:
        if args.compute_backend != "xla":
            raise SystemExit(
                "--compute-backend applies to --executor galaxy (the zoo "
                "executor has no padded ExecPlan shards to shed)")
        params = init_params(cfg, jax.random.PRNGKey(0))
        executor = TransformerExecutor(params, cfg)

    tracer = None
    if args.trace is not None:
        from repro.obs import Tracer
        tracer = Tracer()
    drift = None
    if args.drift:
        from repro.core import costmodel
        from repro.core.execplan import ExecPlan
        from repro.core.simulator import make_step_pricer
        from repro.obs import DriftMonitor

        # the galaxy executor exposes the exact plan it runs; the zoo path
        # is priced as a single-device even plan.  Nominal device/link
        # specs — run experiments/calibrate.py for fitted ones; the drift
        # *trend* (ratio p50 moving over time) is meaningful either way
        eplan = (executor.plan if args.executor == "galaxy" else
                 ExecPlan.even(1, num_heads=cfg.num_heads, d_ff=cfg.d_ff,
                               head_dim=cfg.head_dim, d_model=cfg.d_model))
        devices = [costmodel.jetson_nano("nano-l", 4.0)
                   for _ in range(eplan.num_devices)]
        drift = DriftMonitor(make_step_pricer(
            eplan, cfg, devices, costmodel.mbps(1000)))

    engine = ServingEngine(
        executor=executor,
        max_batch=args.max_batch,
        max_len=args.prompt_len + args.max_new,
        sampler=SamplerConfig(temperature=args.temperature),
        scheduler=args.scheduler,
        page_size=args.page_size,
        prefix_cache=args.prefix_cache == "on",
        prefill_chunk=args.prefill_chunk,
        draft_executor=draft_executor,
        spec_k=args.spec_k,
        # TTFT/ITL histograms fill from the record_times stamps
        record_times=bool(args.metrics or args.trace or args.drift),
        tracer=tracer,
        drift=drift,
    )

    rng = np.random.default_rng(0)
    # with the prefix cache on, model the traffic it targets: a shared
    # system prompt (half the prompt) ahead of each request's own tail
    shared = (rng.integers(0, cfg.vocab_size, size=args.prompt_len // 2).tolist()
              if args.prefix_cache == "on" else [])
    for i in range(args.requests):
        tail = rng.integers(
            0, cfg.vocab_size, size=args.prompt_len - len(shared)).tolist()
        engine.submit(Request(uid=i, prompt=shared + tail,
                              max_new_tokens=args.max_new))

    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    new_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests in {dt:.2f}s "
          f"({new_tokens} new tokens, {new_tokens/dt:,.1f} tok/s)")
    print(f"stats: {engine.stats}")
    if args.spec_k is not None:
        s = engine.stats
        print(f"speculative: k={args.spec_k} rounds={s['spec_steps']} "
              f"proposed={s['spec_proposed']} accepted={s['spec_accepted']} "
              f"acceptance={s['spec_acceptance']:.1%} "
              f"accept_counts={dict(sorted(s['spec_accept_counts'].items()))}")
    if engine.prefix_stats is not None:
        print(f"prefix cache: {engine.prefix_stats}")
    if tracer is not None:
        tracer.write(args.trace)
        print(f"trace: {len(tracer.events)} events -> {args.trace}")
    if args.metrics:
        print("metrics snapshot:")
        print(json.dumps(engine.metrics.snapshot(), indent=2, default=float))
        print(engine.metrics.to_prometheus(), end="")
    if drift is not None:
        print("sim-vs-measured drift (measured/simulated ratio):")
        for kind, s in drift.summary().items():
            print(f"  {kind}: n={s['n']} p50={s['p50']:.2f} "
                  f"p95={s['p95']:.2f}")


if __name__ == "__main__":
    main()
