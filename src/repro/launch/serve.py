"""End-to-end serving driver: batched requests through the wave scheduler.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduce \
      --requests 16 --prompt-len 32 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import init_params
from repro.serving import Request, SamplerConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ASSIGNED_ARCHS)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--scheduler", choices=("auto", "continuous", "wave"),
                    default="auto",
                    help="auto = continuous batching when the executor "
                         "implements the paged protocol, else waves")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV pool page size (continuous batching)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    if cfg.input_mode != "token":
        raise SystemExit(f"{cfg.name} is a stub-frontend arch; serve the token archs")

    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(
        params, cfg,
        max_batch=args.max_batch,
        max_len=args.prompt_len + args.max_new,
        sampler=SamplerConfig(temperature=args.temperature),
        scheduler=args.scheduler,
        page_size=args.page_size,
    )

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len).tolist()
        engine.submit(Request(uid=i, prompt=prompt, max_new_tokens=args.max_new))

    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    new_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests in {dt:.2f}s "
          f"({new_tokens} new tokens, {new_tokens/dt:,.1f} tok/s)")
    print(f"stats: {engine.stats}")


if __name__ == "__main__":
    main()
