"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (jax locks the device count on first backend init).

Single pod:  16 x 16 = 256 chips, axes (data, model)
Multi-pod:   2 x 16 x 16 = 512 chips, axes (pod, data, model)

The "model" axis is the Galaxy HMP axis (TP heads/ffn/experts + SP sequence);
"data" carries batch / FSDP weight shards / long-context cache shards; "pod"
is the cross-pod (DCN-class) data axis.

``make_mesh_compat`` papers over the jax version split: ``AxisType`` (and
the ``axis_types=`` kwarg) only exist in newer jax; on older versions plain
``jax.make_mesh`` already yields Auto-mode axes.  Every mesh in this repo —
src, tests, benchmarks — should go through it.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

try:  # jax >= 0.5: explicit-sharding types exist; ask for Auto
    from jax.sharding import AxisType

    _AUTO = (AxisType.Auto,)
except ImportError:  # older jax: all mesh axes are Auto-equivalent
    AxisType = None
    _AUTO = None


def make_mesh_compat(shape: Sequence[int], axes: Sequence[str], *,
                     devices: Optional[Sequence] = None):
    """``jax.make_mesh`` with Auto axis types on any supported jax version.

    ``devices`` selects an explicit device subset (e.g. the first 4 of 8
    forced host devices, to run a 4-device plan under an 8-device process).
    """
    if devices is not None:
        import numpy as np
        from jax.sharding import Mesh

        arr = np.asarray(devices).reshape(tuple(shape))
        if _AUTO is not None:
            return Mesh(arr, tuple(axes), axis_types=_AUTO * len(axes))
        return Mesh(arr, tuple(axes))
    if _AUTO is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=_AUTO * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_test_mesh(model: int = 2, data: int = 1):
    """Small mesh for CPU multi-device tests (subprocess with forced device
    count)."""
    return make_mesh_compat((data, model), ("data", "model"))
