"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (jax locks the device count on first backend init).

Single pod:  16 x 16 = 256 chips, axes (data, model)
Multi-pod:   2 x 16 x 16 = 512 chips, axes (pod, data, model)

The "model" axis is the Galaxy HMP axis (TP heads/ffn/experts + SP sequence);
"data" carries batch / FSDP weight shards / long-context cache shards; "pod"
is the cross-pod (DCN-class) data axis.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(model: int = 2, data: int = 1):
    """Small mesh for CPU multi-device tests (subprocess with forced device
    count)."""
    axes = ("data", "model")
    return jax.make_mesh((data, model), axes, axis_types=(AxisType.Auto,) * 2)
