from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.shapes import SHAPES, input_specs, shape_config

__all__ = ["make_production_mesh", "make_test_mesh", "SHAPES", "input_specs", "shape_config"]
