"""Data pipeline: synthetic LM streams + text-file-backed corpora, packed
into fixed-shape (B, S) batches with next-token labels.

Synthetic mode draws from a Zipfian unigram distribution with a Markov
bigram structure so the loss curve is non-trivial (a learnable signal for
the end-to-end training example).  Multimodal archs (input_mode='embed')
get deterministic pseudo-embedding features.  Host sharding: each process
takes a strided slice of the batch index space (single-process here, but
the interface is multi-host ready).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import ByteTokenizer


@dataclasses.dataclass
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0
    text_path: Optional[str] = None
    process_index: int = 0
    process_count: int = 1


def _zipf_markov_stream(rng: np.random.Generator, vocab: int, n: int) -> np.ndarray:
    """Zipf unigram + shift-structured bigram: token t+1 is correlated with
    token t, giving a model something learnable."""
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    base = rng.choice(vocab, size=n, p=probs)
    out = base.copy()
    stay = rng.random(n) < 0.5
    out[1:][stay[1:]] = (out[:-1][stay[1:]] + 1) % vocab
    return out.astype(np.int32)


class LMDataPipeline:
    def __init__(self, cfg: ModelConfig, data_cfg: DataConfig):
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.tokenizer = ByteTokenizer()
        self._text_ids: Optional[np.ndarray] = None
        if data_cfg.text_path:
            with open(data_cfg.text_path, "rb") as f:
                raw = f.read()
            self._text_ids = np.frombuffer(raw, dtype=np.uint8).astype(np.int32)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        dc = self.data_cfg
        rng = np.random.default_rng(dc.seed + 7919 * dc.process_index)
        b, s = dc.batch_size, dc.seq_len
        vocab = min(self.cfg.vocab_size, 4096)
        while True:
            if self._text_ids is not None and len(self._text_ids) > (s + 1):
                starts = rng.integers(0, len(self._text_ids) - s - 1, size=b)
                chunk = np.stack([self._text_ids[i : i + s + 1] for i in starts])
            else:
                chunk = _zipf_markov_stream(rng, vocab, b * (s + 1)).reshape(b, s + 1)
            tokens, labels = chunk[:, :-1], chunk[:, 1:]
            batch: Dict[str, np.ndarray] = {"labels": np.ascontiguousarray(labels)}
            if self.cfg.input_mode == "token":
                batch["tokens"] = np.ascontiguousarray(tokens)
            else:
                # stubbed modality frontend: deterministic pseudo-embeddings
                d = self.cfg.d_model
                feats = _token_features(tokens, d)
                batch["embeds"] = feats
                if self.cfg.num_codebooks > 1:
                    cb = self.cfg.num_codebooks
                    batch["labels"] = np.stack(
                        [(labels + i) % self.cfg.vocab_size for i in range(cb)], axis=-1
                    ).astype(np.int32)
            if self.cfg.num_image_tokens:
                img_rng = np.random.default_rng(dc.seed + 13)
                batch["img_embeds"] = img_rng.standard_normal(
                    (b, self.cfg.num_image_tokens, self.cfg.d_model), dtype=np.float32
                ) * 0.1
            yield batch


def _token_features(tokens: np.ndarray, d: int) -> np.ndarray:
    """Deterministic pseudo-embedding of a token id (stub frontend)."""
    b, s = tokens.shape
    phase = tokens[..., None].astype(np.float32)
    freqs = np.arange(1, d + 1, dtype=np.float32) / d
    return (np.sin(phase * freqs * 0.1) * 0.3).astype(np.float32)
