from repro.data.pipeline import DataConfig, LMDataPipeline
from repro.data.tokenizer import ByteTokenizer

__all__ = ["DataConfig", "LMDataPipeline", "ByteTokenizer"]
