"""Byte-level tokenizer: 256 byte tokens + BOS/EOS/PAD specials.
Self-contained (no external vocab files) and reversible."""
from __future__ import annotations

from typing import List

PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB_SIZE = 259


class ByteTokenizer:
    vocab_size = VOCAB_SIZE
    pad_id = PAD_ID
    bos_id = BOS_ID
    eos_id = EOS_ID

    def encode(self, text: str, add_bos: bool = True, add_eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8"))
        if add_bos:
            ids = [BOS_ID] + ids
        if add_eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids) -> str:
        data = bytes(i for i in ids if 0 <= i < 256)
        return data.decode("utf-8", errors="replace")
