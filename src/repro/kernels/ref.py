"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,H,Sq,hd); k,v: (B,Hkv,Sk,hd) with H % Hkv == 0. fp32 softmax."""
    b, h, sq, hd = q.shape
    hkv = k.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, sq, hd)
    scores = jnp.einsum("bkgsh,bkth->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd)
    sk = k.shape[2]
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)  # right-aligned positions
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,bkth->bkgsh", p, v)
    return out.reshape(b, h, sq, hd)


def tiled_gemm_ref(x, w):
    """x: (M,K) @ w: (K,N) with fp32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def fused_connective_ref(x, res, keep_mask, scale, bias, *, rate: float, eps: float = 1e-5):
    """The Galaxy SP connective block: dropout -> residual add -> layernorm.
    x, res: (S, d); keep_mask: (S, d) float 0/1 (ignored when rate == 0)."""
    if rate > 0:
        x = x * keep_mask / (1.0 - rate)
    y = (x + res).astype(jnp.float32)
    mu = y.mean(-1, keepdims=True)
    var = ((y - mu) ** 2).mean(-1, keepdims=True)
    out = (y - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(x.dtype)


def rglru_scan_ref(a, b, h0):
    """Sequential oracle of h_t = a_t ⊙ h_{t-1} + b_t. a,b: (B,S,w); h0: (B,w)."""
    import jax

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    h_last, hs = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (a.transpose(1, 0, 2).astype(jnp.float32),
         b.transpose(1, 0, 2).astype(jnp.float32)),
    )
    return hs.transpose(1, 0, 2).astype(a.dtype), h_last.astype(a.dtype)
