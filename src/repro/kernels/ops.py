"""Public jit'd wrappers for the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (the kernel
body executes in Python via the Pallas interpreter — functionally identical
to the TPU lowering).  On a real TPU backend ``interpret`` defaults to
False and the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_connective import fused_connective as _connective
from repro.kernels.tiled_gemm import tiled_gemm as _gemm


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128):
    return _flash(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=_default_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def tiled_gemm(x, w, *, block_m=256, block_n=256, block_k=512):
    return _gemm(
        x, w, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=_default_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("rate", "eps", "block_s"))
def fused_connective(x, res, keep_mask, scale, bias, *, rate=0.0, eps=1e-5, block_s=256):
    return _connective(
        x, res, keep_mask, scale, bias, rate=rate, eps=eps, block_s=block_s,
        interpret=_default_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("block_s", "block_w"))
def rglru_scan(a, b, h0, *, block_s=256, block_w=256):
    from repro.kernels.rglru_scan import rglru_scan_kernel

    return rglru_scan_kernel(
        a, b, h0, block_s=block_s, block_w=block_w,
        interpret=_default_interpret(),
    )
