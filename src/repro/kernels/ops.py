"""Public wrappers for the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (the kernel
body executes in Python via the Pallas interpreter — functionally identical
to the TPU lowering).  On a real TPU backend ``interpret`` defaults to
False and the same calls compile to Mosaic.

Besides the standalone jit'd wrappers, this module is the dispatch point of
the ``ExecPlan.compute_backend`` knob: :func:`gemm` and
:func:`ragged_attention` are what the HMP executor (``core/hmp.py``) and the
ring primitives (``core/ring.py``) call per shard.  ``backend="xla"`` keeps
the padded dense einsum (the pad-and-mask correctness oracle);
``backend="pallas"`` routes through the valid-length kernels, whose grids
skip pad blocks so executed MXU work tracks each device's *assigned* units
instead of ``max(units)``.  These run inside jitted shard_map bodies, so
they are plain functions (no extra jit layer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.flash_attention import ragged_flash_attention as _ragged_flash
from repro.kernels.fused_connective import fused_connective as _connective
from repro.kernels.tiled_gemm import divisor_block
from repro.kernels.tiled_gemm import tiled_gemm as _gemm
from repro.kernels.tiled_gemm import tiled_gemm_valid as _gemm_valid


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# --- compute-backend dispatch (ExecPlan.compute_backend) ----------------------

COMPUTE_BACKENDS = ("xla", "pallas")


def gemm(x, w, *, backend: str = "xla", valid_m=None, valid_n=None,
         valid_k=None, seg_n=None, block_m: int = 128, block_n: int = 128,
         block_k: int = 512, count_blocks: bool = False):
    """(..., M, K) @ (K, N) through the selected compute backend.

    Leading dims of ``x`` fold into the GEMM M axis as equal segments (one
    per batch row), each with ``valid_m`` real leading rows.  ``valid_n``
    names the real leading columns of each ``seg_n``-column segment of
    ``w`` (e.g. the q/k/v thirds of a fused QKV weight) and ``valid_k`` the
    real contraction prefix.  Valid counts may be traced scalars — they are
    per-device quantities inside shard_map.

    xla: a dense dot over the padded shapes with the valid counts applied
    as masks (every pad block still executes — the SPMD oracle), so both
    backends compute the identical function of the valid regions whatever
    the pad regions hold.  pallas: the valid-length tiled kernel, shedding
    whole pad blocks.  ``count_blocks=True`` (pallas only) also returns
    the measured live-block count.
    """
    if backend not in COMPUTE_BACKENDS:
        raise ValueError(f"unknown compute backend {backend!r}; "
                         f"one of {COMPUTE_BACKENDS}")
    if backend == "xla":
        if count_blocks:
            raise ValueError("count_blocks is a pallas-backend measurement")
        m, kk = x.shape[-2], x.shape[-1]
        n = w.shape[1]
        if valid_m is not None:
            rows = jnp.arange(m) < valid_m
            x = jnp.where(rows[:, None], x, 0)
        if valid_k is not None:
            cols = jnp.arange(kk) < valid_k
            x = jnp.where(cols[None, :], x, 0)
        out = jnp.einsum("...mk,kn->...mn", x, w)
        if valid_n is not None:
            seg = n if seg_n is None else seg_n
            keep = (jnp.arange(n) % seg) < valid_n
            out = jnp.where(keep, out, 0)
        return out
    lead = x.shape[:-2]
    seg_m = x.shape[-2]
    x2 = x.reshape(-1, x.shape[-1])
    out = _gemm_valid(
        x2, w, valid_m=valid_m, valid_n=valid_n, valid_k=valid_k,
        seg_m=seg_m, seg_n=seg_n, block_m=block_m, block_n=block_n,
        block_k=block_k, count_blocks=count_blocks,
        interpret=_default_interpret(),
    )
    if count_blocks:
        out, cnt = out
        return out.reshape(*lead, seg_m, w.shape[1]), cnt
    return out.reshape(*lead, seg_m, w.shape[1])


def ragged_attention(q, k, v, *, positions, valid_heads=None,
                     block_q: int = 128, block_k: int = 128):
    """Causal attention over a padded ragged row order, (B, S, H, hd)
    executor layout.  ``positions`` is the static ``SeqLayout.positions``
    map (-1 = pad row; ``arange`` for a dense layout) and ``valid_heads``
    this device's real head count (traced scalar ok).  Pad rows/heads come
    out exactly zero; always the pallas path (the xla equivalent is the
    caller's masked einsum)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _ragged_flash(
        qt, kt, vt, positions=positions, valid_heads=valid_heads,
        block_q=block_q, block_k=block_k, interpret=_default_interpret(),
    )
    return out.transpose(0, 2, 1, 3)


def connective(x, res, scale, bias, *, block_s: int = 256):
    """Fused residual-add + layernorm over (..., S, d) activations — the
    Galaxy connective block as one HBM pass (dropout disabled at
    inference).  Used by the pallas backend in place of the unfused
    residual + LN pair."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    res2 = res.reshape(-1, res.shape[-1])
    # rate=0: the keep-mask operand is never read — alias x itself rather
    # than streaming a materialized all-ones buffer through VMEM
    out = _connective(
        x2, res2, x2, scale, bias, rate=0.0,
        block_s=divisor_block(x2.shape[0], block_s),
        interpret=_default_interpret(),
    )
    return out.reshape(*lead, x.shape[-1])


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128):
    return _flash(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=_default_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def tiled_gemm(x, w, *, block_m=256, block_n=256, block_k=512):
    return _gemm(
        x, w, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=_default_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("rate", "eps", "block_s"))
def fused_connective(x, res, keep_mask, scale, bias, *, rate=0.0, eps=1e-5, block_s=256):
    return _connective(
        x, res, keep_mask, scale, bias, rate=rate, eps=eps, block_s=block_s,
        interpret=_default_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("block_s", "block_w"))
def rglru_scan(a, b, h0, *, block_s=256, block_w=256):
    from repro.kernels.rglru_scan import rglru_scan_kernel

    return rglru_scan_kernel(
        a, b, h0, block_s=block_s, block_w=block_w,
        interpret=_default_interpret(),
    )
