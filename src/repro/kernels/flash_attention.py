"""Blocked (flash) attention Pallas kernel for TPU.

Design for the TPU memory hierarchy (DESIGN.md §2): Q/K/V blocks are staged
HBM->VMEM by BlockSpecs with MXU-aligned tiles (block_q x head_dim and
block_k x head_dim, multiples of 128 where shapes allow); the kernel keeps
the running max / normalizer / accumulator in VMEM scratch across the
sequential k-block grid axis (TPU grids iterate the last axis innermost),
which is the standard online-softmax accumulation pattern.

Supports causal and sliding-window masks (RecurrentGemma local attention,
and the long_500k sliding-window variant) and GQA via the kv-head index
map (q head h reads kv head h // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, sk: int, sq: int, block_q: int,
            block_k: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (block_q, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (block_k, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    # positions (queries right-aligned against the key sequence)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + (sk - sq)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    # fully-masked rows: keep accumulator stable
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(
    q, k, v, *, causal: bool = True, window: int = 0,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
):
    """q: (B,H,Sq,hd); k,v: (B,Hkv,Sk,hd). Returns (B,H,Sq,hd)."""
    b, h, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    scale = 1.0 / (hd ** 0.5)

    grid = (b, h, sq // block_q, sk // block_k)
    kernel = functools.partial(
        _kernel, causal=causal, window=window, sk=sk, sq=sq,
        block_q=block_q, block_k=block_k, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
