"""Blocked (flash) attention Pallas kernel for TPU.

Design for the TPU memory hierarchy (DESIGN.md §2): Q/K/V blocks are staged
HBM->VMEM by BlockSpecs with MXU-aligned tiles (block_q x head_dim and
block_k x head_dim, multiples of 128 where shapes allow); the kernel keeps
the running max / normalizer / accumulator in VMEM scratch across the
sequential k-block grid axis (TPU grids iterate the last axis innermost),
which is the standard online-softmax accumulation pattern.

Supports causal and sliding-window masks (RecurrentGemma local attention,
and the long_500k sliding-window variant) and GQA via the kv-head index
map (q head h reads kv head h // group).

:func:`ragged_flash_attention` is the ``compute_backend="pallas"`` variant
for the HMP hot loop: queries/keys live in an ``execplan.SeqLayout`` padded
ragged order (position per padded row, -1 for pad rows), a static
block-level skip map derived from those positions prunes (q-block, k-block)
pairs that are entirely pad or entirely acausal, and a per-device
``valid_heads`` scalar-prefetch operand skips padded head slots outright —
so executed attention FLOPs track the plan's assigned heads, not
``max(heads)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiled_gemm import divisor_block

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, sk: int, sq: int, block_q: int,
            block_k: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (block_q, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (block_k, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    # positions (queries right-aligned against the key sequence)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + (sk - sq)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    # fully-masked rows: keep accumulator stable
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(
    q, k, v, *, causal: bool = True, window: int = 0,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
):
    """q: (B,H,Sq,hd); k,v: (B,Hkv,Sk,hd). Returns (B,H,Sq,hd)."""
    b, h, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError(
            f"attention ({sq} q x {sk} k) does not tile into blocks "
            f"(block_q={block_q}, block_k={block_k}); blocks must divide"
        )
    scale = 1.0 / (hd ** 0.5)

    grid = (b, h, sq // block_q, sk // block_k)
    kernel = functools.partial(
        _kernel, causal=causal, window=window, sk=sk, sq=sq,
        block_q=block_q, block_k=block_k, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# --- ragged (SeqLayout-aware) variant ----------------------------------------

def attention_block_map(positions, block_q: int, block_k: int) -> np.ndarray:
    """Static (nq, nk) skip map of a ragged causal attention.

    ``positions[r]`` is the real position padded row ``r`` holds (-1 for pad
    rows).  A (q-block, k-block) pair is live iff some valid key in the
    k-block is causally visible to some valid query in the q-block; for a
    dense ``arange`` layout this reduces to the standard causal block skip.
    The layout is trace-time static (it comes from ``ExecPlan.seq_layout``),
    so the map is plain numpy and enters the kernel as a scalar-prefetch
    operand.
    """
    pos = np.asarray(positions, int)
    (s,) = pos.shape
    if s % block_q or s % block_k:
        raise ValueError(
            f"positions ({s} rows) do not tile into blocks "
            f"(block_q={block_q}, block_k={block_k})"
        )
    nq, nk = s // block_q, s // block_k
    live = np.zeros((nq, nk), np.int32)
    for qi in range(nq):
        qp = pos[qi * block_q:(qi + 1) * block_q]
        qp = qp[qp >= 0]
        if not qp.size:
            continue
        for ki in range(nk):
            kp = pos[ki * block_k:(ki + 1) * block_k]
            kp = kp[kp >= 0]
            if kp.size and kp.min() <= qp.max():
                live[qi, ki] = 1
    return live


def _ragged_kernel(vh_ref, bm_ref, q_ref, k_ref, v_ref, pq_ref, pk_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float):
    hi = pl.program_id(1)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip pad head slots (per-device scalar) and pruned block pairs
    live = (hi < vh_ref[0]) & (bm_ref[qi, ki] > 0)

    @pl.when(live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)   # (block_q, hd)
        kk = k_ref[0, 0].astype(jnp.float32)  # (block_k, hd)
        vv = v_ref[0, 0].astype(jnp.float32)

        s = jnp.dot(q, kk.T, preferred_element_type=jnp.float32) * scale
        pq = pq_ref[...]
        pk = pk_ref[...]
        mask = (pq[:, None] >= 0) & (pk[None, :] >= 0) \
            & (pk[None, :] <= pq[:, None])
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        # fully-masked rows keep the accumulator stable (exp guard)
        p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, vv, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        # rows with no live contribution (pad queries, pad heads) emit zero
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def ragged_flash_attention(
    q, k, v, *, positions, valid_heads=None,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
):
    """Causal flash attention over a padded ragged row order.

    q: (B,H,S,hd); k,v: (B,Hkv,S,hd); positions: (S,) static int row->real
    position (-1 = pad row).  ``valid_heads`` (traced scalar ok) marks the
    leading real head slots of this device's padded shard — padded heads
    and pruned (q, k) block pairs are skipped entirely, pad query rows come
    out exactly zero, and valid rows match ``flash_attention_ref`` over the
    compacted sequence.
    """
    b, h, s, hd = q.shape
    hkv = k.shape[1]
    g = h // hkv
    block_q = divisor_block(s, block_q)
    block_k = divisor_block(s, block_k)
    scale = 1.0 / (hd ** 0.5)

    block_map = attention_block_map(positions, block_q, block_k)
    vh = jnp.asarray(h if valid_heads is None else valid_heads,
                     jnp.int32).reshape(1)
    pos = jnp.asarray(positions, jnp.int32)

    grid = (b, h, s // block_q, s // block_k)
    kernel = functools.partial(_ragged_kernel, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # valid_heads, block skip map
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, hi, qi, ki, vh, bm: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki, vh, bm: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki, vh, bm: (bi, hi // g, ki, 0)),
            pl.BlockSpec((block_q,), lambda bi, hi, qi, ki, vh, bm: (qi,)),
            pl.BlockSpec((block_k,), lambda bi, hi, qi, ki, vh, bm: (ki,)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, hi, qi, ki, vh, bm: (bi, hi, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        interpret=interpret,
    )(vh, jnp.asarray(block_map), q, k, v, pos, pos)
