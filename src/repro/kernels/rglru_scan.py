"""RG-LRU sequence-scan Pallas kernel (RecurrentGemma's recurrent hot-spot).

h_t = a_t ⊙ h_{t-1} + b_t over the sequence, per (batch, width-tile).  The
XLA associative_scan builds a log-depth tree that materializes O(log S)
full (B,S,w) intermediates in HBM; this kernel streams (block_s x block_w)
tiles through VMEM sequentially per grid row, carrying h in a VMEM scratch
— one HBM read of (a,b) and one write of h, O(1) intermediates.  The
diagonal recurrence has no cross-width dependencies, so the width grid
dimension is embarrassingly parallel (and model-axis shardable).

Trade-off vs associative_scan (documented for the §Perf log): sequential
in S per core but ~log2(S) x less HBM traffic; on TPU the recurrence is
memory-bound so the traffic term dominates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, o_ref, hlast_ref, carry):
    si = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(si == 0)
    def _init():
        carry[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)  # (block_s, block_w)
    b = b_ref[0].astype(jnp.float32)

    # sequential recurrence within the tile via scan over rows
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    h_last, hs = jax.lax.scan(step, carry[...], (a, b))
    o_ref[0] = hs.astype(o_ref.dtype)
    carry[...] = h_last

    @pl.when(si == ns - 1)
    def _finish():
        hlast_ref[0] = h_last.astype(hlast_ref.dtype)


def rglru_scan_kernel(
    a, b, h0, *, block_s: int = 256, block_w: int = 256, interpret: bool = False,
):
    """a, b: (B, S, w); h0: (B, w).  Returns (h_seq (B,S,w), h_last (B,w))."""
    bsz, s, w = a.shape
    block_s = min(block_s, s)
    block_w = min(block_w, w)
    assert s % block_s == 0 and w % block_w == 0

    grid = (bsz * (w // block_w), s // block_s)
    nw = w // block_w

    def idx_sw(i, si):
        return (i // nw, si, i % nw)

    def idx_w(i, si):
        return (i // nw, i % nw)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), idx_sw),
            pl.BlockSpec((1, block_s, block_w), idx_sw),
            pl.BlockSpec((1, block_w), idx_w),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_w), idx_sw),
            pl.BlockSpec((1, block_w), idx_w),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, w), a.dtype),
            jax.ShapeDtypeStruct((bsz, w), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
