"""Pallas TPU kernels for the perf-critical compute the paper optimizes:
the overlap tile GEMM (§III-D), blocked attention, and the fused SP
connective block.  Validated in interpret mode against kernels/ref.py."""
from repro.kernels import ops, ref  # noqa: F401
