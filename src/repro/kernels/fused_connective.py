"""Fused connective-block Pallas kernel: dropout -> residual add -> layernorm.

The paper's motivation for SP on connective blocks is that these element-wise
ops are *memory-bandwidth* bound (§III-B-3): executed separately they make
3-4 passes over the activations.  This kernel fuses them into a single
HBM->VMEM->HBM pass over (block_s x d) tiles — one read of x / residual /
mask, one write — cutting connective-block traffic ~3x (see roofline notes).

Dropout consumes a precomputed keep-mask (generated with jax.random outside)
so the kernel is deterministic and bit-reproducible across schedules.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, res_ref, mask_ref, scale_ref, bias_ref, o_ref, *,
            rate: float, eps: float):
    x = x_ref[...].astype(jnp.float32)
    if rate > 0:
        x = x * mask_ref[...].astype(jnp.float32) / (1.0 - rate)
    y = x + res_ref[...].astype(jnp.float32)
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(y - mu), axis=-1, keepdims=True)
    out = (y - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale_ref[...].astype(jnp.float32) + bias_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


def fused_connective(
    x, res, keep_mask, scale, bias, *, rate: float = 0.0, eps: float = 1e-5,
    block_s: int = 256, interpret: bool = False,
):
    """x, res, keep_mask: (S, d); scale, bias: (d,).  One pass over HBM."""
    s, d = x.shape
    block_s = min(block_s, s)
    if s % block_s:
        raise ValueError(
            f"connective of {s} rows does not tile into block_s={block_s} "
            "blocks; the block must divide the row count"
        )
    grid = (s // block_s,)
    kernel = functools.partial(_kernel, rate=rate, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, d), lambda i: (i, 0)),
            pl.BlockSpec((block_s, d), lambda i: (i, 0)),
            pl.BlockSpec((block_s, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_s, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), x.dtype),
        interpret=interpret,
    )(x, res, keep_mask, scale, bias)
