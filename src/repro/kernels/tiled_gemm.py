"""MXU-aligned tiled GEMM Pallas kernels.

This is the compute primitive of the paper's tile-based overlap (§III-D):
each ring step's per-tile GEMM is exactly one of these calls on a sequence
tile.  BlockSpecs stage (block_m x block_k) / (block_k x block_n) operand
tiles into VMEM with a fp32 VMEM accumulator; the k grid axis is innermost
so the accumulator lives across the contraction.  128-multiples align the
MXU's 128x128 systolic array.

Two entry points:

* :func:`tiled_gemm` — the dense kernel (all blocks computed).
* :func:`tiled_gemm_valid` — the *valid-length* kernel behind the
  ``compute_backend="pallas"`` ExecPlan path: per-device valid row/column/
  contraction counts enter as scalar-prefetch operands, the grid skips
  blocks that lie entirely in the pad region (no MXU issue, and the block
  counter does not tick), and the straddling block is masked in the
  epilogue.  A device holding 2 of max=4 padded head slots therefore runs
  ~half the MXU work of the pad-and-mask SPMD oracle instead of a
  mask-multiply over the full padded shard.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def divisor_block(extent: int, preferred: int) -> int:
    """Largest block size <= ``preferred`` that divides ``extent``.

    Keeps kernel callers shape-agnostic: MXU-aligned preferences are used
    when shapes allow, tiny test shapes degrade to exact divisors instead
    of erroring.
    """
    if extent <= 0:
        raise ValueError(f"cannot pick a block for extent {extent}")
    b = min(preferred, extent)
    while extent % b:
        b -= 1
    return b


def _validate_tiling(m: int, n: int, k: int, block_m: int, block_n: int,
                     block_k: int) -> None:
    # a bare assert would vanish under ``python -O`` and resurface as an
    # opaque XLA shape error; name the offending shapes/blocks instead
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"GEMM ({m}x{k}) @ ({k}x{n}) does not tile into blocks "
            f"(block_m={block_m}, block_n={block_n}, block_k={block_k}): "
            "every block size must divide its axis — pick divisors or use "
            "kernels.tiled_gemm.divisor_block"
        )


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def tiled_gemm(
    x, w, *, block_m: int = 256, block_n: int = 256, block_k: int = 512,
    interpret: bool = False,
):
    """x: (M, K) @ w: (K, N) -> (M, N), fp32 accumulation in VMEM."""
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(
            f"GEMM contraction mismatch: x is ({m}x{k}) but w is ({k2}x{n})"
        )
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    _validate_tiling(m, n, k, block_m, block_n, block_k)

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w)


# --- valid-length GEMM (the ExecPlan pad-shedding backend) --------------------

def _valid_kernel(v_ref, x_ref, w_ref, o_ref, cnt_ref, acc_ref, *,
                  block_m: int, block_n: int, block_k: int,
                  seg_m: int, seg_n: int):
    """Grid cell (mi, ni, ki); ``v_ref`` prefetches (valid_m, valid_n,
    valid_k).  The M and N axes are segments of ``seg_m``/``seg_n`` entries
    with a valid *prefix* each (e.g. each batch row's sequence tile, or each
    of the q/k/v column groups of a fused QKV weight); blocks never straddle
    segments (block | seg is enforced by the wrapper).  A block whose
    segment offset lies past the valid prefix is pure padding: the dot is
    skipped and the live-block counter does not tick."""
    mi = pl.program_id(0)
    ni = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    vm, vn, vk = v_ref[0], v_ref[1], v_ref[2]
    live = (
        ((mi * block_m) % seg_m < vm)
        & ((ni * block_n) % seg_n < vn)
        & (ki * block_k < vk)
    )

    @pl.when((mi == 0) & (ni == 0) & (ki == 0))
    def _reset_count():
        cnt_ref[0, 0] = 0

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _accumulate():
        xb = x_ref[...]
        # zero the contraction tail of the straddling K block so garbage in
        # pad columns of x (times garbage pad rows of w) cannot contribute
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, xb.shape, 1)
        xb = jnp.where(kpos < vk, xb, 0)
        acc_ref[...] += jnp.dot(xb, w_ref[...],
                                preferred_element_type=jnp.float32)
        cnt_ref[0, 0] += 1

    @pl.when(ki == nk - 1)
    def _epilogue():
        # mask the straddling M/N blocks: pad rows/columns come out exactly
        # zero no matter what the pad regions of x and w held
        rows = (mi * block_m
                + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 0)) % seg_m
        cols = (ni * block_n
                + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 1)) % seg_n
        keep = (rows < vm) & (cols < vn)
        o_ref[...] = jnp.where(keep, acc_ref[...], 0).astype(o_ref.dtype)


def tiled_gemm_valid(
    x, w, *, valid_m=None, valid_n=None, valid_k=None,
    seg_m: int | None = None, seg_n: int | None = None,
    block_m: int = 128, block_n: int = 128, block_k: int = 512,
    count_blocks: bool = False, interpret: bool = False,
):
    """Valid-length (M, K) @ (K, N) -> (M, N) that sheds pad blocks.

    valid_m: real leading rows of each ``seg_m``-row M segment (traced
             scalar ok — it is a per-device quantity inside shard_map);
             pad rows of the output are exactly zero.
    valid_n: real leading columns of each ``seg_n``-column N segment; pad
             columns of the output are exactly zero.
    valid_k: real leading entries of the contraction axis; the pad tail
             contributes exactly zero regardless of operand contents.
    seg_m/seg_n: segment extents (default: one segment spanning the axis).
             Block sizes are shrunk to divisors of their segment so no
             block straddles a segment boundary.

    ``None`` valid counts mean fully dense on that axis.  With
    ``count_blocks=True`` also returns the number of (m, n, k) blocks the
    kernel actually issued a dot for — the measured effective-work
    counter ``benchmarks/microbench.py:execplan_padshed`` reports.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(
            f"GEMM contraction mismatch: x is ({m}x{k}) but w is ({k2}x{n})"
        )
    seg_m = m if seg_m is None else seg_m
    seg_n = n if seg_n is None else seg_n
    if m % seg_m or n % seg_n:
        raise ValueError(
            f"segments (seg_m={seg_m}, seg_n={seg_n}) must divide the "
            f"GEMM extents ({m}x{n})"
        )
    block_m = divisor_block(seg_m, block_m)
    block_n = divisor_block(seg_n, block_n)
    block_k = divisor_block(k, block_k)
    _validate_tiling(m, n, k, block_m, block_n, block_k)

    valid = jnp.stack([
        jnp.asarray(seg_m if valid_m is None else valid_m, jnp.int32),
        jnp.asarray(seg_n if valid_n is None else valid_n, jnp.int32),
        jnp.asarray(k if valid_k is None else valid_k, jnp.int32),
    ])
    kernel = functools.partial(
        _valid_kernel, block_m=block_m, block_n=block_n, block_k=block_k,
        seg_m=seg_m, seg_n=seg_n,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // block_m, n // block_n, k // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki, v: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki, v: (ki, ni)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda mi, ni, ki, v: (mi, ni)),
            pl.BlockSpec((1, 1), lambda mi, ni, ki, v: (0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    out, cnt = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(valid, x, w)
    if count_blocks:
        return out, cnt[0, 0]
    return out


def dense_block_count(
    m: int, n: int, k: int, *, valid_m=None, valid_n=None, valid_k=None,
    seg_m: int | None = None, seg_n: int | None = None,
    block_m: int = 128, block_n: int = 128, block_k: int = 512,
) -> int:
    """Analytic live-block count of :func:`tiled_gemm_valid` — the
    cross-check for the kernel's measured counter: segments times
    ``ceil(valid/block)`` per axis."""
    seg_m = m if seg_m is None else seg_m
    seg_n = n if seg_n is None else seg_n
    block_m = divisor_block(seg_m, block_m)
    block_n = divisor_block(seg_n, block_n)
    block_k = divisor_block(k, block_k)
    vm = seg_m if valid_m is None else int(valid_m)
    vn = seg_n if valid_n is None else int(valid_n)
    vk = k if valid_k is None else int(valid_k)
    live_m = (m // seg_m) * -(-vm // block_m)
    live_n = (n // seg_n) * -(-vn // block_n)
    live_k = -(-vk // block_k)
    return live_m * live_n * live_k
