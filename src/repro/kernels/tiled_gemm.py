"""MXU-aligned tiled GEMM Pallas kernel.

This is the compute primitive of the paper's tile-based overlap (§III-D):
each ring step's per-tile GEMM is exactly one of these calls on a sequence
tile.  BlockSpecs stage (block_m x block_k) / (block_k x block_n) operand
tiles into VMEM with a fp32 VMEM accumulator; the k grid axis is innermost
so the accumulator lives across the contraction.  128-multiples align the
MXU's 128x128 systolic array.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def tiled_gemm(
    x, w, *, block_m: int = 256, block_n: int = 256, block_k: int = 512,
    interpret: bool = False,
):
    """x: (M, K) @ w: (K, N) -> (M, N), fp32 accumulation in VMEM."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w)
