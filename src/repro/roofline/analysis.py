"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step per chip:

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s          (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_chip / HBM_bw               (819 GB/s)
  collective = collective_bytes_per_chip / link_bw       (~50 GB/s/link ICI)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (the partitioned,
per-device module).  collective_bytes is parsed from the post-SPMD HLO text:
for every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute we sum the *output* tensor bytes (per-device received
volume; all-reduce counted twice — RS + AG of the ring implementation).

MODEL_FLOPS = 6·N·D (training) or 2·N·D (inference forward), N_active for
MoE; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.core.costmodel import TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)

# received-volume multiplier per op.  NOTE: the CPU backend decomposes
# reduce-scatter into all-reduce + dynamic-slice, so all-reduce here usually
# stands for what a TPU lowers as a ReduceScatter — weight 1.0 (received
# bytes counted once) is the closer approximation of the TPU schedule.
_OP_WEIGHT = {
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "all-reduce": 1.0,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-op-type received bytes (per device), from post-SPMD HLO."""
    out: Dict[str, float] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, op, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # counted at -start
        b = _shape_bytes(shape_str) * _OP_WEIGHT[op]
        out[op] = out.get(op, 0.0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # per chip
    hlo_bytes: float          # per chip (HBM traffic)
    coll_bytes: Dict[str, float]  # per chip
    model_flops: float        # global useful FLOPs (6ND / 2ND)
    peak_mem_bytes: Optional[float] = None
    # XLA:CPU promotes bf16 tensors to f32; a bf16 model's HBM/ICI traffic on
    # TPU is therefore ~half of what the CPU-compiled HLO reports.
    dtype_factor: float = 1.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / TPU_V5E["peak_flops"]

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes * self.dtype_factor / TPU_V5E["hbm_bw"]

    @property
    def t_collective(self) -> float:
        return self.coll_bytes.get("total", 0.0) * self.dtype_factor / TPU_V5E["ici_bw"]

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def step_time(self) -> float:
        """Roofline lower bound on step time (terms overlap-free)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline bound."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return self.model_flops / self.chips / t / TPU_V5E["peak_flops"]

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_chip": self.hlo_flops,
            "hlo_bytes_per_chip": self.hlo_bytes,
            "collective_bytes_per_chip": self.coll_bytes,
            "model_flops_global": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_ratio,
            "roofline_step_s": self.step_time,
            "roofline_mfu": self.mfu,
            "peak_mem_bytes_per_chip": self.peak_mem_bytes,
        }


def model_flops(cfg, shape_info: Dict, training: bool) -> float:
    """6·N·D (train) or 2·N·D (inference), N = active params."""
    n = cfg.param_count(active_only=True)
    if shape_info["mode"] == "train":
        tokens = shape_info["batch"] * shape_info["seq"]
        return 6.0 * n * tokens
    if shape_info["mode"] == "prefill":
        tokens = shape_info["batch"] * shape_info["seq"]
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape_info["batch"]
