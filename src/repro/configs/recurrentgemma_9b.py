"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000. Griffin-style RG-LRU + local attention at a 2:1 ratio
(pattern rec,rec,attn; 38 = 12 groups of 3 + 2 trailing rec blocks).
Local attention window 2048. [arXiv:2402.19427]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,             # griffin uses wide heads (16*256 = 4096)
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    window=2048,              # local attention — natively sub-quadratic
    lru_width=4096,
    conv_width=4,
    norm="rmsnorm",
    activation="geglu",
    tie_embeddings=True,
)
