"""Base model configuration for all architecture families.

Every assigned architecture (and the Galaxy paper's own evaluation models)
is expressed as a single ``ModelConfig``.  The transformer assembly in
``repro.models.transformer`` consumes only this dataclass, so new
architectures are added by writing one config file.

Block patterns
--------------
``block_pattern`` is the repeating unit of the layer stack, e.g.::

    dense            ("attn",)
    recurrentgemma   ("rec", "rec", "attn")      # Griffin 1:2 ratio
    xlstm            ("mlstm", "slstm")
    llama-vision     ("attn",)*4 + ("xattn",)    # cross-attn every 5th

``num_layers`` need not be a multiple of ``len(block_pattern)``; the
remainder blocks (``num_layers % len(pattern)``) are instantiated
individually after the scanned groups (see models/transformer.py).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

# Attention kinds usable inside a block pattern.
ATTN_KINDS = ("attn", "xattn")
RECURRENT_KINDS = ("rec", "mlstm", "slstm")
BLOCK_KINDS = ATTN_KINDS + RECURRENT_KINDS


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # --- identity -------------------------------------------------------
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    source: str  # citation for the config (paper / model card)

    # --- core dims ------------------------------------------------------
    num_layers: int = 12
    d_model: int = 768
    num_heads: int = 12
    num_kv_heads: int = 12
    d_ff: int = 3072          # dense MLP width; for MoE: per-expert width
    vocab_size: int = 32000
    head_dim: int = 0          # 0 -> d_model // num_heads

    # --- block structure --------------------------------------------------
    block_pattern: Tuple[str, ...] = ("attn",)
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    activation: str = "swiglu"  # swiglu | geglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    pos_embedding: str = "rope"  # rope | sinusoidal | none
    rope_theta: float = 10000.0
    dropout_rate: float = 0.0   # paper's connective block includes dropout

    # --- attention ------------------------------------------------------
    window: int = 0             # 0 = full causal; >0 = sliding-window (hybrid local attn)
    # sliding-window width substituted for full attention ONLY for the
    # long_500k input shape on otherwise-quadratic archs (see DESIGN.md §4)
    long_context_window: int = 4096

    # --- MoE --------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    router_jitter: float = 0.0
    load_balance_loss_weight: float = 0.01
    moe_capacity_factor: float = 2.0   # GShard capacity; dispatch cost ∝ cf

    # --- recurrent (RG-LRU / Griffin) -------------------------------------
    lru_width: int = 0          # 0 -> d_model
    conv_width: int = 4

    # --- xLSTM ------------------------------------------------------------
    proj_factor: float = 2.0    # up-projection inside m/sLSTM blocks
    mlstm_chunk: int = 128      # chunkwise-parallel scan chunk

    # --- multimodal stubs ---------------------------------------------------
    # "token": inputs are int token ids; "embed": inputs are precomputed
    # frontend embeddings (B, S, d_model) — audio/vlm stub carve-out.
    input_mode: str = "token"
    num_image_tokens: int = 0   # vlm: patch-embedding count fed to cross-attn
    num_codebooks: int = 0      # audio: parallel codebook heads (0 = single head)

    # --- numerics ---------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True          # checkpoint each block group during training
    # "full" recomputes everything; "dots" saves matmul outputs (cheaper
    # backward compute, more activation memory); "none" disables remat.
    remat_policy: str = "full"
    # query-chunked attention for long prefill (0 = off): caps the live
    # score buffer at (B, H, chunk, S) instead of (B, H, S, S)
    attn_chunk: int = 0

    # ----------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)
        for kind in self.block_pattern:
            if kind not in BLOCK_KINDS:
                raise ValueError(f"unknown block kind {kind!r}")
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError("num_heads must be divisible by num_kv_heads")

    # --- derived ------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def padded_vocab(self, multiple: int = 256) -> int:
        """Vocab rounded up so the vocab dim shards evenly over the mesh."""
        return _round_up(self.vocab_size, multiple)

    def padded_experts(self, multiple: int) -> int:
        """Experts padded so the expert dim shards evenly (padding experts
        receive -inf router logits and are never selected)."""
        if not self.is_moe:
            return 0
        return _round_up(self.num_experts, multiple)

    @property
    def num_groups(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def tail_pattern(self) -> Tuple[str, ...]:
        r = self.num_layers % len(self.block_pattern)
        return self.block_pattern[:r]

    def layer_kinds(self) -> Tuple[str, ...]:
        """Kind of every layer, in order."""
        return self.block_pattern * self.num_groups + self.tail_pattern

    def count_kind(self, kind: str) -> int:
        return sum(1 for k in self.layer_kinds() if k == kind)

    @property
    def attention_free(self) -> bool:
        return all(k in RECURRENT_KINDS for k in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if prefill/decode cost is sub-quadratic in sequence length
        natively (recurrent blocks and/or windowed attention only)."""
        for k in self.block_pattern:
            if k in ATTN_KINDS and self.window == 0:
                return False
        return True

    # --- parameter counting (used for roofline MODEL_FLOPS = 6·N·D) ---------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.head_dim
        h, kv = self.num_heads, self.num_kv_heads
        n = 0
        if self.input_mode == "token":
            n += self.vocab_size * d
        if not self.tie_embeddings:
            n += self.vocab_size * d * max(1, self.num_codebooks or 1)
        gate_mats = {"swiglu": 3, "geglu": 3, "gelu": 2}[self.activation]
        for kind in self.layer_kinds():
            if kind in ("attn", "xattn"):
                n += d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d  # qkvo
                if self.is_moe:
                    e = self.experts_per_token if active_only else self.num_experts
                    n += e * gate_mats * d * self.d_ff + d * self.num_experts
                elif self.d_ff > 0:
                    n += gate_mats * d * self.d_ff
            elif kind == "rec":
                w = self.lru_width
                n += 2 * d * w + w * d          # in/out projections (gated)
                n += self.conv_width * w + 3 * w  # conv + lru gates
                n += gate_mats * d * self.d_ff    # hybrid blocks keep MLP
            elif kind == "mlstm":
                f = self.proj_factor
                di = int(d * f)
                n += 2 * d * di + di * d + 3 * di * di // max(self.num_heads, 1)
            elif kind == "slstm":
                f = self.proj_factor
                di = int(d * f)
                n += d * 4 * di + di * 4 * di + di * d  # in, recurrent, out
        return int(n)


def reduced(cfg: ModelConfig, d_model: int = 256, vocab: int = 512) -> ModelConfig:
    """Smoke-test variant: one pattern group of layers (>=2 for dense),
    d_model <= 512, <= 4 experts — same family/code paths, CPU-runnable."""
    pat = cfg.block_pattern
    layers = max(2, len(pat))
    heads = min(cfg.num_heads, 4)
    kv = min(cfg.num_kv_heads, heads)
    while heads % kv:
        kv -= 1
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=0,
        d_ff=0 if cfg.d_ff == 0 else max(64, d_model * 2),
        vocab_size=vocab,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        lru_width=0,
        window=min(cfg.window, 32) if cfg.window else 0,
        long_context_window=64,
        num_image_tokens=min(cfg.num_image_tokens, 16),
        dtype="float32",
        param_dtype="float32",
        remat=False,
    )
