"""The five Transformer models the Galaxy paper evaluates (Table IV).

These drive the paper-reproduction benchmarks (simulator + real single-host
microbenchmarks); the assigned production architectures live in their own
config files.  All are encoder- or decoder-only stacks of the Fig. 2 layer:
MHA block + MLP block joined by connective (dropout/residual/layernorm)
blocks — exactly what HMP partitions.
"""
from repro.configs.base import ModelConfig


def _paper_model(name: str, layers: int, heads: int, hidden: int) -> ModelConfig:
    return ModelConfig(
        name=name,
        family="dense",
        source="Galaxy paper Table IV",
        num_layers=layers,
        d_model=hidden,
        num_heads=heads,
        num_kv_heads=heads,
        d_ff=4 * hidden,           # paper §II-A: MLP expands h -> 4h -> h
        vocab_size=50304,
        block_pattern=("attn",),
        norm="layernorm",
        activation="gelu",
        pos_embedding="sinusoidal",
        dropout_rate=0.1,
        dtype="float16",           # paper runs fp16 (§II-B GPT2-L footprint)
        param_dtype="float16",
    )


DISTILBERT = _paper_model("distilbert", 6, 12, 768)
BERT_L = _paper_model("bert-l", 24, 16, 1024)
GPT2_L = _paper_model("gpt2-l", 36, 20, 1280)
OPT_L = _paper_model("opt-l", 24, 16, 2048)
OPT_XL = _paper_model("opt-xl", 32, 32, 2560)

PAPER_MODELS = {
    "distilbert": DISTILBERT,
    "bert-l": BERT_L,
    "gpt2-l": GPT2_L,
    "opt-l": OPT_L,
    "opt-xl": OPT_XL,
}
