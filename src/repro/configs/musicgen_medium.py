"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.
Decoder-only transformer over EnCodec tokens. The EnCodec conv codec frontend
is STUBBED per the task carve-out: input_specs() feeds precomputed frame
embeddings (B, S, d_model); the backbone predicts 4 parallel codebooks of
2048 codes each. [arXiv:2306.05284]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    block_pattern=("attn",),
    norm="layernorm",
    activation="gelu",
    pos_embedding="sinusoidal",
    input_mode="embed",       # EnCodec frontend stub
    num_codebooks=4,
)
