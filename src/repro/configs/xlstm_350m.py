"""xlstm-350m [ssm] — 24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304.
Alternating mLSTM (chunkwise-parallel matrix memory) and sLSTM (sequential
scalar memory with exponential gating) blocks. d_ff=0: the up/down
projections live inside each block (proj_factor=2). [arXiv:2405.04517]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    proj_factor=2.0,
    mlstm_chunk=128,
    norm="layernorm",
    activation="gelu",
    pos_embedding="none",     # recurrence encodes position
)
