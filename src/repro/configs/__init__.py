"""Architecture config registry.

``get_config("<arch-id>")`` resolves both the assigned production
architectures (by their public ids, e.g. ``--arch qwen1.5-0.5b``) and the
Galaxy paper's own evaluation models (``--arch bert-l``).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig, reduced  # noqa: F401

# arch-id -> module under repro.configs
_ASSIGNED = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "musicgen-medium": "musicgen_medium",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen1.5-110b": "qwen1_5_110b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "stablelm-12b": "stablelm_12b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "xlstm-350m": "xlstm_350m",
}

ASSIGNED_ARCHS = tuple(_ASSIGNED)


def get_config(name: str) -> ModelConfig:
    if name in _ASSIGNED:
        mod = importlib.import_module(f"repro.configs.{_ASSIGNED[name]}")
        return mod.CONFIG
    from repro.configs.paper_models import PAPER_MODELS

    if name in PAPER_MODELS:
        return PAPER_MODELS[name]
    raise KeyError(
        f"unknown arch {name!r}; known: {sorted(_ASSIGNED) + ['distilbert', 'bert-l', 'gpt2-l', 'opt-l', 'opt-xl']}"
    )


def all_configs() -> Dict[str, ModelConfig]:
    return {name: get_config(name) for name in ASSIGNED_ARCHS}
