"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32) d_ff=13440
vocab=92416, QKV bias. [hf:Qwen/CodeQwen1.5-7B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    source="hf:Qwen/CodeQwen1.5-7B",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    block_pattern=("attn",),
    norm="rmsnorm",
    activation="swiglu",
    qkv_bias=True,
)
