"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256. Cross-attention image layers interleaved with self-attention
(pattern: 4 self + 1 cross, 20 groups = 100 layers). The ViT/SigLIP vision
encoder + projector are STUBBED per the task carve-out: input_specs() feeds
precomputed patch embeddings (B, num_image_tokens, d_model).
[hf:meta-llama/Llama-3.2-11B-Vision, 90B variant]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=("attn", "attn", "attn", "attn", "xattn"),
    norm="rmsnorm",
    activation="swiglu",
    num_image_tokens=1024,    # stubbed vision frontend output length
)
