"""qwen1.5-110b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-0.5B family card, 110B variant]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    block_pattern=("attn",),
    norm="rmsnorm",
    activation="swiglu",
    qkv_bias=True,
)
