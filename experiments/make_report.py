"""Regenerate the EXPERIMENTS.md roofline/dry-run tables from the JSONs in
experiments/dryrun/.  Usage: python experiments/make_report.py [mesh]
"""
import glob
import json
import os
import sys

HERE = os.path.dirname(__file__)
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, suffix: str = ""):
    rows = []
    for p in sorted(glob.glob(os.path.join(HERE, "dryrun", f"*__{mesh}{suffix}.json"))):
        name = os.path.basename(p)
        if suffix == "" and "__tp_only" in name:
            continue
        rows.append(json.load(open(p)))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    return rows


def roofline_table(mesh: str, suffix: str = "") -> str:
    rows = load(mesh, suffix)
    out = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
        "| bottleneck | 6ND/HLO | roofline MFU | temp GB/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} "
            f"| {r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} "
            f"| **{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_mfu']:.3f} | {(r['temp_bytes'] or 0)/1e9:.1f} |"
        )
    return "\n".join(out)


def collective_mix(mesh: str) -> str:
    rows = load(mesh)
    out = ["| arch | shape | AG MB | AR MB | A2A MB | CP MB | total MB |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        c = r["collective_bytes_per_chip"]
        out.append(
            "| {arch} | {shape} | {ag:.0f} | {ar:.0f} | {a2a:.0f} | {cp:.0f} | {tot:.0f} |".format(
                arch=r["arch"], shape=r["shape"],
                ag=c.get("all-gather", 0) / 1e6, ar=c.get("all-reduce", 0) / 1e6,
                a2a=c.get("all-to-all", 0) / 1e6,
                cp=c.get("collective-permute", 0) / 1e6, tot=c.get("total", 0) / 1e6,
            )
        )
    return "\n".join(out)


if __name__ == "__main__":
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print(roofline_table(mesh))
    print()
    print(collective_mix(mesh))
