"""§Perf hillclimbs: the three chosen (arch x shape) pairs, iterated per
the hypothesis -> change -> measure -> validate methodology.  Each variant
re-lowers + re-analyses against the single-pod production mesh and saves a
tagged JSON next to the baselines.

Pairs (chosen from the 40-combo baseline table):
  1. granite-moe-3b-a800m / train_4k   — worst roofline MFU (0.040)
  2. qwen1.5-110b / decode_32k         — most collective-bound (2.04 s)
  3. codeqwen1.5-7b / prefill_32k      — most paper-representative
                                          (single-shot inference prefill)

Run: python experiments/hillclimb.py  (sets its own XLA device flags)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


from repro.launch import dryrun


def coordinate_hillclimb(loss_fn, params, *, factors=(0.5, 0.8, 1.25, 2.0),
                         rounds=8, verbose=False):
    """Generic multiplicative coordinate descent over named scalar params.

    Repeatedly tries scaling each parameter by each factor, keeping any
    move that lowers ``loss_fn(params)``; stops after ``rounds`` sweeps or
    when no single move improves.  Returns ``(best_params, best_loss)``.
    Used by experiments/calibrate.py to fit cost-model constants to the
    measured microbench residuals — the same hypothesis -> change ->
    measure -> validate loop as the dry-run variants below, but automated.
    """
    best = dict(params)
    best_loss = loss_fn(best)
    for _ in range(rounds):
        improved = False
        for name in list(best):
            for f in factors:
                cand = dict(best)
                cand[name] = best[name] * f
                loss = loss_fn(cand)
                if loss < best_loss - 1e-12:
                    best, best_loss, improved = cand, loss, True
                    if verbose:
                        print(f"  {name} x{f} -> loss {loss:.4f}", flush=True)
        if not improved:
            break
    return best, best_loss


def report(tag, r):
    print(
        f"[{tag}] tc={r['t_compute_s']:.4f} tm={r['t_memory_s']:.4f} "
        f"tcoll={r['t_collective_s']:.4f} bneck={r['bottleneck']} "
        f"useful={r['useful_flops_ratio']:.2f} mfu={r['roofline_mfu']:.3f} "
        f"temp={(r['temp_bytes'] or 0)/1e9:.1f}GB args={(r['argument_bytes'] or 0)/1e9:.1f}GB",
        flush=True,
    )


def main():
    only = sys.argv[1] if len(sys.argv) > 1 else ""

    runs = [
        # --- #1 granite-moe train_4k -------------------------------------
        ("granite-moe-3b-a800m", "train_4k", "h1a_remat_dots",
         dict(remat_policy="dots"), {}),
        ("granite-moe-3b-a800m", "train_4k", "h1b_cf125",
         dict(remat_policy="dots", moe_capacity_factor=1.25), {}),
        ("granite-moe-3b-a800m", "train_4k", "h1c_attnchunk",
         dict(remat_policy="dots", moe_capacity_factor=1.25, attn_chunk=1024), {}),
        # --- #2 qwen1.5-110b decode_32k -------------------------------------
        ("qwen1.5-110b", "decode_32k", "h2a_weights_model_only",
         {}, dict(serve_weights_model_only=True)),
        ("qwen1.5-110b", "decode_32k", "h2b_fp8_weights",
         dict(param_dtype="float8_e4m3fn"), dict(serve_weights_model_only=True)),
        # --- #3 codeqwen prefill_32k ----------------------------------------
        ("codeqwen1.5-7b", "prefill_32k", "h3a_attnchunk",
         dict(attn_chunk=2048), {}),
    ]
    for arch, shape, tag, cfg_over, rules_over in runs:
        if only and only not in tag:
            continue
        try:
            r = dryrun.run_one(arch, shape, variant=tag, cfg_overrides=cfg_over,
                               rules_overrides=rules_over, verbose=False)
            report(f"{arch}/{shape}/{tag}", r)
        except Exception as e:  # noqa: BLE001
            print(f"[{tag}] FAILED: {type(e).__name__}: {str(e)[:300]}", flush=True)

    # paper-faithful comparison: Megatron-TP layout (no SP) on the
    # paper-representative pair — quantifies HMP's gain in roofline terms
    if not only or "tponly" in only:
        try:
            r = dryrun.run_one("codeqwen1.5-7b", "prefill_32k",
                               hmp_sequence_parallel=False, verbose=False)
            report("codeqwen1.5-7b/prefill_32k/tp_only_baseline", r)
        except Exception as e:  # noqa: BLE001
            print(f"[tp_only] FAILED: {e}", flush=True)


if __name__ == "__main__":
    main()
