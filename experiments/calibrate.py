"""Measured-vs-simulated calibration loop (ROADMAP item).

``benchmarks/microbench.py:execplan_uneven`` reports the simulator's score
and the measured wall time of the *same* uneven ExecPlan; this experiment
closes the loop: it measures hmp / hmp_ring per-layer wall times on this
host (forced CPU devices), then hillclimbs the cost-model constants of a
"host device" (effective FLOP/s, memory bandwidth, the emulated
interconnect's bandwidth/latency, and the simulator's TILE_OVERHEAD) until
``simulate_execplan`` reproduces the measurements.  Residuals are squared
log-ratios, so over- and under-prediction weigh equally.

Run:  PYTHONPATH=src python experiments/calibrate.py

Writes experiments/calibration.json with the fitted constants, the loss
trajectory, and per-scenario residuals.  The fitted ``tile_overhead`` can
be fed back via ``costmodel.apply_calibration({"TILE_OVERHEAD": ...})``;
the host device/link constants parameterize future simulate() calls that
score this host instead of a Jetson cluster.
"""
import dataclasses
import json
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from experiments.hillclimb import coordinate_hillclimb  # noqa: E402

# starting guesses for a laptop/CI-class host running 4 forced XLA CPU
# devices: per-"device" FLOP/s, memory bandwidth, and the shared-memory
# "interconnect" XLA emulates for ppermute/collectives
DEFAULT_CONSTANTS = {
    "host_flops": 2.0e10,
    "host_bw": 1.0e10,
    "link_bw": 5.0e9,
    "link_lat": 1e-4,
    "tile_overhead": 0.05,
}

SEQ = 128
CAPS = [3.0, 2.0, 2.0, 1.0]


def _plan_and_cfg():
    from repro.configs import get_config
    from repro.core import costmodel
    from repro.core.execplan import ExecPlan
    from repro.core.profiler import AnalyticProfiler

    cfg = dataclasses.replace(get_config("distilbert"), num_layers=1)
    devices = [
        costmodel.DeviceSpec(f"edge{i}", flops=c * 7.1e9, mem_bw=4.0e9,
                             memory_budget=1.5e9)
        for i, c in enumerate(CAPS)
    ]
    prof = AnalyticProfiler(cfg, SEQ)
    eplan = ExecPlan.from_plan(prof.plan(devices), head_dim=cfg.head_dim,
                               d_model=cfg.d_model)
    return cfg, eplan


def measure() -> dict:
    """Wall time (seconds/layer) of hmp / hmp_ring for the canonical uneven
    plan on 4 forced CPU devices — the measured side of the residuals.
    Uses the same harness as the execplan benches, so calibration closes
    the loop on exactly what ``benchmarks/run.py`` reports."""
    from benchmarks.microbench import measure_execplan_layers

    _, eplan = _plan_and_cfg()
    return measure_execplan_layers(eplan, SEQ)


def simulated(constants: dict) -> dict:
    """Simulate the same plan on a cluster of host-modeled devices."""
    from repro.core import costmodel
    from repro.core.simulator import simulate_execplan

    cfg, eplan = _plan_and_cfg()
    devices = [
        costmodel.DeviceSpec(f"host{i}", flops=constants["host_flops"],
                             mem_bw=constants["host_bw"], memory_budget=1e12)
        for i in range(len(CAPS))
    ]
    link = costmodel.LinkSpec(bandwidth=constants["link_bw"],
                              latency=constants["link_lat"])
    previous = costmodel.apply_calibration(
        {"TILE_OVERHEAD": constants["tile_overhead"]})
    try:
        # padded=True: the host really executes the SPMD pad-and-mask program
        return {
            "hmp": simulate_execplan(eplan, cfg, devices, link, SEQ,
                                     overlap=False, padded=True).latency,
            "hmp_ring": simulate_execplan(eplan, cfg, devices, link, SEQ,
                                          overlap=True, padded=True).latency,
        }
    finally:
        costmodel.apply_calibration(previous)


def residual_loss(constants: dict, measured: dict) -> float:
    sim = simulated(constants)
    return sum(
        math.log(sim[k] / measured[k]) ** 2 for k in measured
    )


def calibrate(measured: dict = None, *, rounds: int = 8,
              verbose: bool = False) -> dict:
    """Fit the host constants to the measured residuals; returns a report.

    ``measured`` may be injected (tests pass synthetic timings to avoid the
    device subprocess); None measures this host for real.
    """
    measured = measured if measured is not None else measure()
    start_loss = residual_loss(DEFAULT_CONSTANTS, measured)
    best, best_loss = coordinate_hillclimb(
        lambda c: residual_loss(c, measured), DEFAULT_CONSTANTS,
        rounds=rounds, verbose=verbose,
    )
    sim = simulated(best)
    return {
        "measured_s": measured,
        "simulated_s": sim,
        "constants": best,
        "start_loss": start_loss,
        "loss": best_loss,
        "residual_ratio": {k: sim[k] / measured[k] for k in measured},
    }


def main() -> int:
    report = calibrate(verbose=True)
    out = os.path.join(os.path.dirname(__file__), "calibration.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out}")
    for k, v in report["measured_s"].items():
        print(f"  {k}: measured {v*1e3:.2f}ms  simulated "
              f"{report['simulated_s'][k]*1e3:.2f}ms "
              f"(x{report['residual_ratio'][k]:.2f})")
    print(f"  loss {report['start_loss']:.3f} -> {report['loss']:.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
