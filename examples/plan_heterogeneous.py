"""Heterogeneity + memory aware planning (paper Alg. 1) end to end:
profile -> plan -> simulate, on the paper's own edge environments.

    PYTHONPATH=src python examples/plan_heterogeneous.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core import planner, simulator as sim
from repro.core.profiler import AnalyticProfiler


def main():
    cfg = get_config("bert-l")
    for env_id in ("C", "D", "E", "F"):
        devices = cm.edge_env(env_id)
        prof = AnalyticProfiler(cfg, seq=284)
        dev_profiles = prof.device_profiles(devices)
        model_profile = prof.model_profile()
        plan = planner.plan(model_profile, dev_profiles)

        names = "+".join(d.name for d in devices)
        print(f"\nenv {env_id} ({names}):")
        if not plan.feasible:
            print(f"  INFEASIBLE: {plan.reason}")
            continue
        for i, d in enumerate(devices):
            mem = plan.memory_per_device(model_profile)[i] / 1e6
            print(f"  {d.name:9s} heads={int(plan.mha[i]):2d}/16 "
                  f"mlp_cols={int(plan.mlp[i]):4d}/4096 "
                  f"seq={plan.seq[i]*100:.0f}%  mem={mem:.0f}MB "
                  f"(budget {d.memory_budget/1e6:.0f}MB)")
        t = sim.speedup_table(cfg, devices, cm.mbps(125), 284)
        f = lambda v: v if isinstance(v, str) else f"{v:.2f}x"
        print(f"  galaxy latency {t['galaxy_s']:.2f}s | "
              f"vs Megatron-LM {f(t['megatron'])} | vs SP {f(t['sp'])}")


if __name__ == "__main__":
    main()
