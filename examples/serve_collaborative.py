"""Collaborative serving example: batched requests through the serving
engine + the multi-device HMP layer schedules (paper's core loop),
executed for real on forced CPU devices.

    PYTHONPATH=src python examples/serve_collaborative.py

Serving
-------
The engine (``repro.serving.ServingEngine``) runs **continuous batching**
over a paged KV pool whenever the executor implements the paged protocol
(both bundled executors do):

1. ``PagedKVPool`` (``serving/kvpool.py``) owns fixed-size KV pages and a
   block table mapping (slot, logical page) -> physical page; page storage
   lives with the executor — head-sharded exactly like the dense HMP cache
   for ``GalaxyHMPExecutor``, the model-zoo cache pytree for
   ``TransformerExecutor``.
2. A request is admitted the moment a decode slot is free *and* the pool
   can reserve its worst-case page count (deadlock-free admission); its
   prompt prefills straight into its pages (``hmp_prefill(block_row=)``
   scatters prompt KV inside the shard_map on the Galaxy path).
3. Every decode step advances all live slots at their own depths in one
   batched call: the block table gathers each slot's pages, the new KV
   entry scatters back into its page (``hmp_decode(block_table=)``).
4. A request retires on EOS or max-len; its pages return to the free list
   and the freed slot refills from the queue on the same step — no slot
   idles while work is queued, which is where the tokens/sec win over
   wave scheduling comes from (see ``benchmarks/microbench.py:
   continuous_vs_wave``).

``scheduler="wave"`` keeps the legacy lockstep path (same greedy tokens —
the engine-level contract tests pin both executors against it); executors
without the paged protocol fall back to it automatically.  Prompt padding
policy belongs to the executor (``prompt_pad_multiple``): 1 for the
single-device zoo, the mesh size for the SP-sharded Galaxy prefill.

Prompt-heavy traffic adds two continuous-scheduler features (see
``prefix_sharing_demo`` and the ``--prefix-cache on|off`` /
``--prefill-chunk N`` flags here and on ``launch/serve.py``): the
shared-prefix KV cache admission flow — radix-tree lookup of the prompt ->
refcount bump on the hit's shared pages -> suffix-only chunked prefill ->
insert the new full pages for later requests — and chunked prefill, which
interleaves page-sized prefill chunks with decode steps so long prompts
stop stalling live slots.
"""
import os
import subprocess
import sys

REPO = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)


def serve_demo():
    import jax
    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.serving import Request, SamplerConfig, ServingEngine

    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(params, cfg, max_batch=4, max_len=64,
                           sampler=SamplerConfig(temperature=0.8, top_k=20))
    import numpy as np

    rng = np.random.default_rng(0)
    for i in range(10):
        engine.submit(Request(uid=i, prompt=rng.integers(0, 500, 16).tolist(),
                              max_new_tokens=12))
    done = engine.run()
    print(f"served {len(done)} requests; stats={engine.stats}")
    print(f"sample output: {done[0].output}")


def hmp_demo():
    """Run the paper's four schedules on 4 devices (subprocess)."""
    code = (
        "import jax, jax.numpy as jnp\n"
        "from repro.core import hmp\n"
        "from repro.launch.mesh import make_mesh_compat\n"
        "mesh = make_mesh_compat((4,), ('model',))\n"
        "p = hmp.init_layer_params(jax.random.PRNGKey(0), 128, 8, 512)\n"
        "x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 128))\n"
        "ref = hmp.reference_layer(p, x)\n"
        "for name, fn in hmp.SCHEDULES.items():\n"
        "    err = float(jnp.abs(fn(p, x, mesh) - ref).max())\n"
        "    print(f'  {name:10s} matches reference: max_err={err:.2e}')\n"
    )
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    print("HMP schedules on a 4-device ring (paper Fig. 5-7):")
    subprocess.run([sys.executable, "-c", code], env=env, check=True)


def continuous_batching_demo():
    """Continuous batching vs waves on a skewed request mix (single device)."""
    import time

    import jax
    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.serving import Request, ServingEngine, TransformerExecutor

    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    executor = TransformerExecutor(params, cfg)
    print("Continuous batching vs waves (skewed output lengths):")
    for scheduler in ("wave", "continuous"):
        for _ in range(2):  # first pass warms the jit caches
            eng = ServingEngine(executor=executor, max_batch=4, max_len=48,
                                scheduler=scheduler, page_size=8)
            for i in range(12):
                eng.submit(Request(uid=i, prompt=[1 + i] * 8,
                                   max_new_tokens=24 if i % 4 == 0 else 4))
            t0 = time.perf_counter()
            done = eng.run()
            wall = time.perf_counter() - t0
        toks = sum(len(r.output) for r in done)
        print(f"  {scheduler:10s} {toks} tokens in {wall*1e3:6.1f}ms "
              f"({toks/wall:6.1f} tok/s, {eng.stats['decode_steps']} steps)")


def raggedsp_serving_demo():
    """Bandwidth-heterogeneous cluster: the planner solves uneven *sequence*
    tiles from capacity + per-link bandwidth (one slow hop in the ring), and
    the executor runs them as a padded ragged layout — any prompt length,
    no mesh divisibility."""
    code = (
        "import jax, jax.numpy as jnp\n"
        "from repro.core import costmodel, hmp\n"
        "from repro.core.execplan import ExecPlan\n"
        "from repro.core.profiler import AnalyticProfiler\n"
        "from repro.core.simulator import simulate_execplan\n"
        "from repro.configs import get_config\n"
        "import dataclasses\n"
        "from repro.launch.mesh import make_mesh_compat\n"
        "from repro.serving import GalaxyHMPExecutor, Request, ServingEngine\n"
        "cfg = dataclasses.replace(get_config('distilbert'), num_layers=1)\n"
        "caps = [3.0, 2.0, 2.0, 1.0]\n"
        "devs = [costmodel.DeviceSpec(f'edge{i}', flops=c*7.1e9, mem_bw=4e9,\n"
        "                             memory_budget=1.5e9)\n"
        "        for i, c in enumerate(caps)]\n"
        "links = [costmodel.mbps(1000), costmodel.mbps(1000),\n"
        "         costmodel.mbps(100), costmodel.mbps(1000)]  # one slow hop\n"
        "prof = AnalyticProfiler(cfg, 128)\n"
        "pl = prof.plan(devs, links=links)\n"
        "ep = ExecPlan(heads=(6, 4, 4, 2), columns=(24, 16, 16, 8),\n"
        "              head_dim=8, d_model=128,\n"
        "              seq_shares=tuple(pl.seq))  # tiny demo model, real split\n"
        "print('  plan:', ep.describe())\n"
        "eq = simulate_execplan(ExecPlan.from_plan(prof.plan(devs),\n"
        "      head_dim=cfg.head_dim, d_model=cfg.d_model), cfg, devs, links, 128)\n"
        "bw = simulate_execplan(ExecPlan.from_plan(pl, head_dim=cfg.head_dim,\n"
        "      d_model=cfg.d_model), cfg, devs, links, 128)\n"
        "print(f'  simulated/layer: equal {eq.latency*1e3:.1f}ms vs '\n"
        "      f'bandwidth-aware {bw.latency*1e3:.1f}ms '\n"
        "      f'({eq.latency/bw.latency:.2f}x)')\n"
        "mesh = make_mesh_compat((4,), ('model',))\n"
        "layers = hmp.init_stack_params(jax.random.PRNGKey(0), 2, 128, 16, 48)\n"
        "ep = dataclasses.replace(ep, columns=(18, 12, 12, 6))\n"
        "emb = jax.random.normal(jax.random.PRNGKey(7), (500, 128)) * 0.5\n"
        "exe = GalaxyHMPExecutor(layers, emb, ep, mesh)\n"
        "eng = ServingEngine(executor=exe, max_batch=4, max_len=48,\n"
        "                    scheduler='continuous', page_size=8)\n"
        "for i in range(6):\n"
        "    eng.submit(Request(uid=i, prompt=list(range(1 + i, 14 + 2 * i)),\n"
        "                       max_new_tokens=10 if i % 3 == 0 else 4))\n"
        "done = eng.run()\n"
        "print(f'  served {len(done)} requests over ragged sequence tiles; '\n"
        "      f'stats={eng.stats}')\n"
    )
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    print("Ragged SP on a bandwidth-heterogeneous cluster (one 100 Mbps hop):")
    subprocess.run([sys.executable, "-c", code], env=env, check=True)


def overlap_transport_demo():
    """The ring transport knobs (``ExecPlan.with_transport`` /
    ``GalaxyHMPExecutor(transport=..., double_buffer=...)``): "padded"
    ships the straggler's whole sequence tile on every ring hop, while
    "bucketed" ships each tile's bucket-rounded valid rows and
    ``double_buffer=True`` issues the next hop before the GEMM that hides
    it (``core/ring.py`` RingSchedule).  Greedy tokens are bitwise
    identical by construction; the wire savings show up in
    ``ExecPlan.describe()`` and ``RingSchedule.total_wire_rows``."""
    code = (
        "import jax, jax.numpy as jnp\n"
        "from repro.core import hmp\n"
        "from repro.core.execplan import ExecPlan\n"
        "from repro.launch.mesh import make_mesh_compat\n"
        "from repro.serving import GalaxyHMPExecutor, Request, ServingEngine\n"
        "ep = ExecPlan(heads=(6, 4, 4, 2), columns=(24, 16, 16, 8),\n"
        "              head_dim=8, d_model=128,\n"
        "              seq_shares=(3.0, 2.0, 2.0, 1.0))  # uneven seq tiles\n"
        "mesh = make_mesh_compat((4,), ('model',))\n"
        "layers = hmp.init_stack_params(jax.random.PRNGKey(0), 2, 128, 16, 64)\n"
        "emb = jax.random.normal(jax.random.PRNGKey(7), (500, 128)) * 0.5\n"
        "outs = {}\n"
        "for label, kw in [('padded', {}),\n"
        "                  ('bucketed+db', dict(transport='bucketed',\n"
        "                                       double_buffer=True))]:\n"
        "    exe = GalaxyHMPExecutor(layers, emb, ep, mesh, **kw)\n"
        "    print('  plan:', exe.plan.describe())\n"
        "    eng = ServingEngine(executor=exe, max_batch=4, max_len=40,\n"
        "                        scheduler='continuous', page_size=8)\n"
        "    for i in range(6):\n"
        "        eng.submit(Request(uid=i, prompt=list(range(1 + i, 12 + i)),\n"
        "                           max_new_tokens=8 if i % 3 == 0 else 4))\n"
        "    outs[label] = {r.uid: tuple(r.output) for r in eng.run()}\n"
        "assert outs['padded'] == outs['bucketed+db'], 'transports diverged'\n"
        "sched = exe.plan.ring_schedule(128)\n"
        "print('  greedy tokens identical across transports; one rotation'\n"
        "      f' ships {sched.total_wire_rows()} rows vs'\n"
        "      f' {sched.padded_wire_rows()} padded'\n"
        "      f' ({sched.wire_fraction():.0%} of the padded wire)')\n"
    )
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    print("Overlap ring transport (padded vs bucketed + double-buffered):")
    subprocess.run([sys.executable, "-c", code], env=env, check=True)


def padshed_backend_demo():
    """The ``compute_backend`` knob (``ExecPlan.compute_backend`` /
    ``GalaxyHMPExecutor(compute_backend=...)`` / ``launch/serve.py
    --compute-backend``): "xla" runs the padded dense oracle — every device
    executes max(units) work, zeros included — while "pallas" routes every
    per-shard matmul and the prefill attention through the valid-length
    kernels (``kernels/ops.py``), whose grids skip pad blocks so each
    device's MXU work tracks its *assigned* units.  Greedy tokens are
    identical by construction; ``ExecPlan.describe()`` shows the per-device
    effective-vs-padded FLOPs the shedding recovers."""
    code = (
        "import jax, jax.numpy as jnp\n"
        "from repro.core import hmp, planner\n"
        "from repro.core.execplan import ExecPlan\n"
        "from repro.core.planner import DeviceProfile, ModelProfile\n"
        "from repro.launch.mesh import make_mesh_compat\n"
        "from repro.serving import GalaxyHMPExecutor, Request, ServingEngine\n"
        "caps = [3.0, 2.0, 2.0, 1.0]\n"
        "model = ModelProfile('demo', 2, 16, 256, 1e6, 2e6)\n"
        "devs = [DeviceProfile(f'd{i}', c, 1e12) for i, c in enumerate(caps)]\n"
        "ep = ExecPlan.from_plan(planner.plan(model, devs), head_dim=8,\n"
        "                        d_model=128)\n"
        "print('  plan:', ep.describe())\n"
        "mesh = make_mesh_compat((4,), ('model',))\n"
        "layers = hmp.init_stack_params(jax.random.PRNGKey(0), 2, 128, 16, 256)\n"
        "emb = jax.random.normal(jax.random.PRNGKey(7), (500, 128)) * 0.5\n"
        "outs = {}\n"
        "for backend in ('xla', 'pallas'):\n"
        "    exe = GalaxyHMPExecutor(layers, emb, ep, mesh,\n"
        "                            compute_backend=backend)\n"
        "    eng = ServingEngine(executor=exe, max_batch=4, max_len=32,\n"
        "                        scheduler='continuous', page_size=8)\n"
        "    for i in range(4):\n"
        "        eng.submit(Request(uid=i, prompt=list(range(1 + i, 11 + i)),\n"
        "                           max_new_tokens=6))\n"
        "    outs[backend] = {r.uid: tuple(r.output) for r in eng.run()}\n"
        "assert outs['xla'] == outs['pallas'], 'backends diverged'\n"
        "print('  greedy tokens identical across xla/pallas backends;'\n"
        "      ' pallas sheds', f'{ep.padding_waste():.0%}', 'pad units')\n"
    )
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    print("Pad-shedding compute backend (xla oracle vs pallas valid-length):")
    subprocess.run([sys.executable, "-c", code], env=env, check=True)


def prefix_sharing_demo(prefix_cache: str = "on", prefill_chunk=16):
    """Shared-prefix KV cache + chunked prefill (the admission flow:
    radix-tree lookup -> shared-page refcount bump -> suffix-only chunked
    prefill).  Requests carrying a common system prompt map its pages to
    the *same* refcounted pool pages (``serving/prefix_cache.py``), so only
    each request's own tail is prefetched — and with ``prefill_chunk`` the
    engine interleaves prefill chunks with decode steps instead of stalling
    live slots.  Prints hit-rate stats from ``PrefixCache.stats()``."""
    import time

    import jax
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.serving import Request, ServingEngine, TransformerExecutor

    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    executor = TransformerExecutor(params, cfg)
    rng = np.random.default_rng(0)
    system_prompt = rng.integers(1, 400, 48).tolist()

    print(f"Shared-prefix KV cache (--prefix-cache {prefix_cache}, "
          f"--prefill-chunk {prefill_chunk}):")
    for on in ([False, True] if prefix_cache == "on" else [False]):
        for _ in range(2):  # first pass warms the jit caches
            eng = ServingEngine(executor=executor, max_batch=4, max_len=96,
                                scheduler="continuous", page_size=8,
                                prefix_cache=on, prefill_chunk=prefill_chunk)
            for i in range(10):
                tail = rng.integers(1, 400, 8).tolist()
                eng.submit(Request(uid=i, prompt=system_prompt + tail,
                                   max_new_tokens=8))
            t0 = time.perf_counter()
            done = eng.run()
            wall = time.perf_counter() - t0
        toks = sum(len(r.output) for r in done)
        label = "prefix cache on " if on else "prefix cache off"
        print(f"  {label} {toks} tokens in {wall*1e3:6.1f}ms "
              f"({toks/wall:6.1f} tok/s, prefilled "
              f"{eng.stats['prefill_tokens']} prompt tokens, "
              f"{eng.stats['peak_shared_pages']} pages shared)")
        if on:
            print(f"  PrefixCache.stats(): {eng.prefix_stats}")


def speculative_decoding_demo():
    """Speculative decoding (``serving/spec.py``): a small draft model on
    the fastest device proposes k tokens per round; the serving executor
    verifies all of them in ONE chunked paged prefill (k+1 rows of
    per-position logits) instead of k sequential decode steps.  Accepted
    drafts emit immediately; the first mismatch rolls the slot back via
    block-table truncation (``PagedKVPool.truncate``).  Greedy tokens are
    bitwise identical to plain decoding — the verifier re-derives the exact
    sequential argmax path, speculation only changes how many mesh steps it
    takes to walk it."""
    import time

    import jax
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.serving import Request, ServingEngine, TransformerExecutor

    cfg = reduced(get_config("qwen1.5-0.5b"))
    target = TransformerExecutor(init_params(cfg, jax.random.PRNGKey(0)), cfg)
    # the demo draft reuses the target weights (acceptance ~100%); a real
    # deployment drafts with a much smaller zoo arch (launch/serve.py
    # --draft-model) so each draft step is cheap
    draft = TransformerExecutor(init_params(cfg, jax.random.PRNGKey(0)), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 400, 12).tolist() for _ in range(4)]

    print("Speculative decoding (draft k=4, verify in one chunk prefill):")
    outs = {}
    for spec_k in (None, 4):
        for _ in range(2):  # first pass warms the jit caches
            eng = ServingEngine(
                executor=target, max_batch=1, max_len=48,
                scheduler="continuous", page_size=8,
                draft_executor=draft if spec_k else None, spec_k=spec_k)
            for i in range(4):
                eng.submit(Request(uid=i, prompt=prompts[i],
                                   max_new_tokens=16))
            t0 = time.perf_counter()
            done = eng.run()
            wall = time.perf_counter() - t0
        outs[spec_k] = {r.uid: tuple(r.output) for r in done}
        toks = sum(len(r.output) for r in done)
        if spec_k is None:
            print(f"  plain decode  {toks} tokens in {wall*1e3:6.1f}ms "
                  f"({eng.stats['decode_steps']} mesh steps)")
        else:
            s = eng.stats
            print(f"  speculative   {toks} tokens in {wall*1e3:6.1f}ms "
                  f"({s['spec_steps']} rounds, "
                  f"acceptance {s['spec_acceptance']:.0%}, "
                  f"accept_counts={dict(sorted(s['spec_accept_counts'].items()))})")
    assert outs[None] == outs[4], "speculation changed greedy tokens"
    print("  greedy tokens bitwise identical spec on/off")


def telemetry_demo(trace_path=None):
    """Serving telemetry (``repro.obs``): the same engine run traced end to
    end.  A ``Tracer`` records one span track per request (queued ->
    prefill -> decode, tiling submit->retire) plus an engine track
    (prefill chunks, decode steps, prefix lookups) and exports Chrome
    trace-event JSON — load it in chrome://tracing or ui.perfetto.dev.
    ``engine.metrics`` is the registry behind ``engine.stats``: TTFT/ITL
    histograms, KV-pool occupancy gauges, prefix hit rate — with run vs
    lifetime scopes (``engine.reset_stats()`` zeroes the run scope) and a
    Prometheus text rendering.  A ``DriftMonitor`` prices every executed
    step with the planner's simulator and histograms measured/simulated
    ratios.  All opt-in: a disabled tracer costs zero calls on the hot
    path, and greedy tokens are bitwise identical telemetry on/off
    (``launch/serve.py --trace/--metrics/--drift`` is the CLI spelling)."""
    import jax
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.core import costmodel
    from repro.core.execplan import ExecPlan
    from repro.core.simulator import make_step_pricer
    from repro.models import init_params
    from repro.obs import DriftMonitor, Tracer
    from repro.serving import Request, ServingEngine, TransformerExecutor

    cfg = reduced(get_config("qwen1.5-0.5b"))
    executor = TransformerExecutor(init_params(cfg, jax.random.PRNGKey(0)), cfg)
    rng = np.random.default_rng(0)
    system_prompt = rng.integers(1, 400, 16).tolist()

    tracer = Tracer()
    eplan = ExecPlan.even(1, num_heads=cfg.num_heads, d_ff=cfg.d_ff,
                          head_dim=cfg.head_dim, d_model=cfg.d_model)
    drift = DriftMonitor(make_step_pricer(
        eplan, cfg, [costmodel.jetson_nano("nano-l", 4.0)],
        costmodel.mbps(1000)))
    eng = ServingEngine(executor=executor, max_batch=4, max_len=64,
                        scheduler="continuous", page_size=8,
                        prefix_cache=True, prefill_chunk=8,
                        record_times=True, tracer=tracer, drift=drift)
    for i in range(8):
        tail = rng.integers(1, 400, 6).tolist()
        eng.submit(Request(uid=i, prompt=system_prompt + tail,
                           max_new_tokens=10 if i % 3 == 0 else 4))
    done = eng.run()

    print("Serving telemetry (tracer + metrics registry + drift monitor):")
    snap = eng.metrics.snapshot()
    ttft, itl = snap["histograms"]["ttft_s"], snap["histograms"]["itl_s"]
    print(f"  served {len(done)} requests; "
          f"ttft p50={ttft['p50']*1e3:.1f}ms itl p50={itl['p50']*1e3:.1f}ms "
          f"prefix_hit_rate={snap['gauges']['prefix_hit_rate']:.0%} "
          f"kv_pages_peak={snap['gauges']['kv_pages_peak']:.0f}")
    spans = [e for e in tracer.to_json()["traceEvents"] if e["ph"] == "X"]
    print(f"  trace: {len(tracer.events)} events, {len(spans)} spans, "
          f"0 left open (open_spans={tracer.open_spans()})")
    if trace_path:
        tracer.write(trace_path)
        print(f"  wrote {trace_path} — open in ui.perfetto.dev")
    d = drift.summary()["all"]
    print(f"  drift (measured/simulated, nominal nano-l specs): "
          f"n={d['n']} p50={d['p50']:.2f} p95={d['p95']:.2f}")
    # the registry scopes runs: reset_stats() zeroes the run scope while
    # lifetime totals survive (the old flat dict silently accumulated)
    eng.reset_stats()
    print(f"  after reset_stats(): run requests="
          f"{eng.stats['requests']}, lifetime="
          f"{eng.metrics.snapshot('lifetime')['counters']['requests']}")


def galaxy_serving_demo():
    """Uneven planner output served end-to-end: plan -> ExecPlan ->
    GalaxyHMPExecutor -> continuous batching over the paged head-sharded
    pool, on a 4-device 3:2:2:1 cluster."""
    code = (
        "import jax, jax.numpy as jnp\n"
        "from repro.core import hmp, planner\n"
        "from repro.core.execplan import ExecPlan\n"
        "from repro.core.planner import DeviceProfile, ModelProfile\n"
        "from repro.launch.mesh import make_mesh_compat\n"
        "from repro.serving import GalaxyHMPExecutor, Request, ServingEngine\n"
        "caps = [3.0, 2.0, 2.0, 1.0]\n"
        "model = ModelProfile('demo', 2, 16, 256, 1e6, 2e6)\n"
        "devs = [DeviceProfile(f'd{i}', c, 1e12) for i, c in enumerate(caps)]\n"
        "pl = planner.plan(model, devs)\n"
        "ep = ExecPlan.from_plan(pl, head_dim=8, d_model=128)\n"
        "print('  plan:', ep.describe())\n"
        "mesh = make_mesh_compat((4,), ('model',))\n"
        "layers = hmp.init_stack_params(jax.random.PRNGKey(0), 2, 128, 16, 256)\n"
        "emb = jax.random.normal(jax.random.PRNGKey(7), (500, 128)) * 0.5\n"
        "exe = GalaxyHMPExecutor(layers, emb, ep, mesh)\n"
        "eng = ServingEngine(executor=exe, max_batch=4, max_len=48,\n"
        "                    scheduler='continuous', page_size=8)\n"
        "for i in range(6):\n"
        "    eng.submit(Request(uid=i, prompt=list(range(1 + i, 15 + i)),\n"
        "                       max_new_tokens=12 if i % 3 == 0 else 4))\n"
        "done = eng.run()\n"
        "print(f'  served {len(done)} requests through the uneven plan; '\n"
        "      f'stats={eng.stats}')\n"
    )
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    print("Galaxy serving on an uneven 3:2:2:1 plan (planner -> ExecPlan -> engine):")
    subprocess.run([sys.executable, "-c", code], env=env, check=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--prefix-cache", choices=("on", "off"), default="on",
                    help="shared-prefix KV cache in prefix_sharing_demo "
                         "(off runs the baseline only)")
    ap.add_argument("--prefill-chunk", type=int, default=16, metavar="N",
                    help="prefill chunk size (tokens) for prefix_sharing_demo")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write telemetry_demo's Chrome trace-event JSON "
                         "here (open in ui.perfetto.dev)")
    args = ap.parse_args()

    serve_demo()
    hmp_demo()
    continuous_batching_demo()
    speculative_decoding_demo()
    galaxy_serving_demo()
    raggedsp_serving_demo()
    overlap_transport_demo()
    padshed_backend_demo()
    prefix_sharing_demo(args.prefix_cache, args.prefill_chunk)
    telemetry_demo(args.trace)
