"""Collaborative serving example: batched requests through the wave
scheduler + the multi-device HMP layer schedules (paper's core loop),
executed for real on forced CPU devices.

    PYTHONPATH=src python examples/serve_collaborative.py
"""
import os
import subprocess
import sys

REPO = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)


def serve_demo():
    import jax
    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.serving import Request, SamplerConfig, ServingEngine

    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(params, cfg, max_batch=4, max_len=64,
                           sampler=SamplerConfig(temperature=0.8, top_k=20))
    import numpy as np

    rng = np.random.default_rng(0)
    for i in range(10):
        engine.submit(Request(uid=i, prompt=rng.integers(0, 500, 16).tolist(),
                              max_new_tokens=12))
    done = engine.run()
    print(f"served {len(done)} requests; stats={engine.stats}")
    print(f"sample output: {done[0].output}")


def hmp_demo():
    """Run the paper's four schedules on 4 devices (subprocess)."""
    code = (
        "import jax, jax.numpy as jnp\n"
        "from jax.sharding import AxisType\n"
        "from repro.core import hmp\n"
        "mesh = jax.make_mesh((4,), ('model',), axis_types=(AxisType.Auto,))\n"
        "p = hmp.init_layer_params(jax.random.PRNGKey(0), 128, 8, 512)\n"
        "x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 128))\n"
        "ref = hmp.reference_layer(p, x)\n"
        "for name, fn in hmp.SCHEDULES.items():\n"
        "    err = float(jnp.abs(fn(p, x, mesh) - ref).max())\n"
        "    print(f'  {name:10s} matches reference: max_err={err:.2e}')\n"
    )
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    print("HMP schedules on a 4-device ring (paper Fig. 5-7):")
    subprocess.run([sys.executable, "-c", code], env=env, check=True)


if __name__ == "__main__":
    serve_demo()
    hmp_demo()
