"""Quickstart: build an assigned architecture, run a forward pass, inspect
the Galaxy HMP sharding plan, and time the paper's parallel schedules.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import apply_model, init_params
from repro.models.params import param_bytes


def main():
    print("assigned architectures:")
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        print(f"  {arch:24s} [{cfg.family:6s}] {cfg.num_layers}L d={cfg.d_model} "
              f"params={cfg.param_count()/1e9:.2f}B "
              f"weights={param_bytes(cfg)/1e9:.1f}GB ({cfg.param_dtype})")

    # run a reduced model end to end on CPU
    cfg = reduced(get_config("qwen1.5-0.5b"))
    print(f"\nforward pass on {cfg.name} ({cfg.param_count()/1e6:.1f}M params)...")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    logits, _, _ = apply_model(params, cfg, tokens=tokens, mode="train")
    print(f"logits: {logits.shape}, finite: {bool(jnp.isfinite(logits).all())}")

    # the HMP layout in one line each
    from repro.models.sharding import make_rules

    rules = make_rules(None, "train")
    print("\nGalaxy HMP logical->mesh mapping (train):")
    for k in ("heads", "ffn", "experts", "seq", "batch", "embed_w"):
        print(f"  {k:10s} -> {rules.mapping[k]}")
    print("TP blocks (heads/ffn/experts on 'model') + SP connective (seq on"
          " 'model')\n= AllGather entering / ReduceScatter exiting each TP"
          " block — paper Fig. 5.")


if __name__ == "__main__":
    main()
