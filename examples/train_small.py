"""End-to-end driver (deliverable b): train a ~100M-param model for a few
hundred steps on synthetic data, with checkpointing.

    PYTHONPATH=src python examples/train_small.py [--steps 300]

~100M params: qwen1.5-0.5b family reduced to d_model=512 keeps the full
code path (rope, GQA, swiglu, tied embeddings) at laptop scale.
"""
import os
import subprocess
import sys

REPO = os.path.join(os.path.dirname(__file__), "..")

if __name__ == "__main__":
    steps = sys.argv[sys.argv.index("--steps") + 1] if "--steps" in sys.argv else "300"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "qwen1.5-0.5b", "--reduce", "--d-model", "512",
         "--steps", steps, "--batch", "8", "--seq", "128",
         "--ckpt", "/tmp/galaxy_train_small", "--ckpt-every", "100"],
        env=env, check=True,
    )
